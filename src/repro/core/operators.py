"""The operator layer of the traversal stack (paper §3.1–§3.2).

One traversal algorithm — level-synchronous shortest-path counting plus
dependency accumulation — runs everywhere in this codebase; what varies
is *how a level is applied* and *how level-global facts are agreed on*.
:class:`TraversalOperator` is that seam.  The engine layer
(:mod:`repro.core.engine`) owns the level loops; the driver layer
(:mod:`repro.core.driver`) owns the per-round algebra and the host round
loop; operators own everything below a level:

  apply(x)                 A @ x over the rows this operator holds
  forward_level(...)       one forward BFS level (default: masked matmul
                           via ``apply``; Pallas operators fuse it)
  backward_level(...)      one dependency level (same contract)
  reduce_any/max/sum       collective agreement on frontier liveness,
                           max depth, and additive per-column facts
                           (identity on a single device; psum/pmax on a
                           2-D grid)
  row_ids / level_cap      which global vertices the local rows are, and
                           the worst-case level count
  root_omega               look up ω at the round's root vertices
  overlap                  collective-schedule policy (OVERLAP_POLICIES):
                           barrier all_gather/psum_scatter vs ppermute
                           ring steps pipelined with block compute

Implementations:

* :class:`DenseOperator`     — [n, n] 0/1 matmul on the MXU (§3.1).
* :class:`SparseOperator`    — padded arc list + gather/segment-sum, the
                               TPU stand-in for atomic scatter-add (§3.1).
* :class:`PallasDenseOperator` — fused level kernels
                               (kernels/frontier_spmm.py,
                               kernels/dependency_spmm.py): one kernel
                               launch per level, no HBM-materialized
                               frontier/g intermediates.
* :class:`DistributedOperator` — the paper's 2-D decomposition (§3.2):
                               expand (all_gather over grid rows) →
                               block-local compute → fold (psum_scatter
                               over grid columns), with arc-list local
                               compute.
* :class:`DistributedPallasOperator` — same collective skeleton, but the
                               block-local compute is the fused Pallas
                               kernel applied to the device's dense
                               adjacency block — the paper's coarse/fine
                               hybrid (cf. Mishra et al.,
                               arXiv:2008.05718) made reachable from the
                               distributed path.
* :class:`DistributedPallasSparseOperator` — the same fused level
                               structure on a blocked-sparse (BCSR) tile
                               list: only nonzero (bm × bk) tiles of the
                               device block are stored and streamed, so
                               adjacency memory is O(nnz_tiles) — the
                               RMAT-scale engine (kernels/blocked_spmm.py).
* :class:`DistributedPallasHybridOperator` — per-cell mix of the two:
                               each device cell streams whichever
                               representation the roofline's
                               bytes-streamed threshold picked for it
                               (roofline/model.cell_kernel_choice), so
                               near-dense community cells run the dense
                               kernels while hyper-sparse off-diagonal
                               cells run the BCSR kernels — under every
                               overlap policy.

``_forward_level`` / ``_backward_level`` below are the *only*
implementations of the level recurrences in the repository; every
non-fused operator routes through them.

Weighted graphs swap the level recurrences for *bucket* recurrences
(delta-stepping, Fan et al. arXiv:1701.05975): the
:class:`WeightedTraversalOperator` family supplies tentative-distance
relaxation (``relax``, with the light/heavy edge split inside the
operator), the path-count equality step (``sigma_step``) and the
dependency equality step (``delta_step``); the bucket loops live in
:func:`repro.core.engine.forward_buckets` /
:func:`repro.core.engine.backward_buckets`.  The distributed weighted
operators reuse the exact expand/fold collective skeleton (all_gather
over grid rows, segment/pmin fold over grid columns) under every overlap
policy — ring-pipelining the bucketed relaxation is future work, so the
weighted path always runs the barrier schedule internally while keeping
the replica-lockstep contract (``sync_axes``) of the unweighted engine.
"""
from __future__ import annotations

from typing import Callable

import math

import jax
import jax.numpy as jnp

__all__ = [
    "TraversalOperator",
    "DenseOperator",
    "SparseOperator",
    "PallasDenseOperator",
    "DistributedOperator",
    "DistributedPallasOperator",
    "DistributedPallasSparseOperator",
    "DistributedPallasHybridOperator",
    "WeightedTraversalOperator",
    "WeightedDenseOperator",
    "WeightedSparseOperator",
    "DistributedWeightedOperator",
    "DistributedWeightedDenseOperator",
    "as_operator",
    "auto_delta",
    "OVERLAP_POLICIES",
    "normalize_overlap",
]

# Collective-schedule policies for the distributed operators (paper §3.3
# Fig. 2 pipelining).  "none" is the barrier schedule — monolithic
# all_gather expand, block compute, psum_scatter fold, every device idle
# through both collectives.  "expand" decomposes the expand into R-1
# ppermute ring steps interleaved with per-chunk block compute
# (collective-matmul style: the next chunk is in flight while the one in
# hand multiplies).  "expand+fold" additionally decomposes the fold into
# a C-1-step reduce ring, so no monolithic collective remains on the
# level's critical path.  Single-device operators have no collectives;
# they accept only "none".
OVERLAP_POLICIES = ("none", "expand", "expand+fold")


def normalize_overlap(policy: str | None) -> str:
    """Validate an overlap policy string (None means "none")."""
    policy = "none" if policy is None else policy
    if policy not in OVERLAP_POLICIES:
        raise ValueError(
            f"unknown overlap policy {policy!r}; expected one of {OVERLAP_POLICIES}"
        )
    return policy


def _ring_perm(axis_size: int) -> list[tuple[int, int]]:
    """ppermute permutation for one ring hop: device s sends to s+1."""
    return [(s, (s + 1) % axis_size) for s in range(axis_size)]


def _forward_level(op: "TraversalOperator", lvl, sigma, depth):
    """One forward BFS level (paper Alg. 2 analogue — the sole copy).

        t = A @ (σ ⊙ [d = lvl-1]);  newly = (t > 0) ∧ (d < 0)
        d := lvl on newly;          σ += t on newly
    """
    frontier = sigma * (depth == lvl - 1)
    contrib = op.apply(frontier)
    newly = (contrib > 0) & (depth < 0)
    depth = jnp.where(newly, lvl, depth)
    sigma = sigma + jnp.where(newly, contrib, 0.0)
    return sigma, depth, newly.any()


def _backward_level(op: "TraversalOperator", lvl, sigma, depth, omega, delta):
    """One dependency level (paper Alg. 4/5 analogue — the sole copy).

        g = (1 + δ + ω) / σ on d = lvl+1;  δ += σ ⊙ (A @ g) on d = lvl

    Checking successors (Madduri et al.) — no predecessor lists.
    """
    omega_col = omega.astype(jnp.float32)[:, None]
    safe_sigma = jnp.where(sigma > 0, sigma, 1.0)
    g = jnp.where(depth == lvl + 1, (1.0 + delta + omega_col) / safe_sigma, 0.0)
    t = op.apply_backward(g)
    return delta + jnp.where(depth == lvl, sigma * t, 0.0)


def _forward_level_checked(op: "TraversalOperator", lvl, sigma, depth):
    """:func:`_forward_level` with a transient ABFT ones-checksum lane.

    The lane is appended to the masked frontier just before the SpMM and
    stripped right after — it never enters the loop carry, so σ/d stay
    [n, s] everywhere and liveness / max-depth are unpolluted.  Returns
    the usual triple plus the relative column-sum residual of this
    level's product (f32 scalar, row-local — no extra collectives).
    """
    from repro.kernels.ops import checksum_append, checksum_residual

    frontier = sigma * (depth == lvl - 1)
    t = op.apply(checksum_append(frontier))
    err = checksum_residual(t)
    contrib = t[:, :-1]
    newly = (contrib > 0) & (depth < 0)
    depth = jnp.where(newly, lvl, depth)
    sigma = sigma + jnp.where(newly, contrib, 0.0)
    return sigma, depth, newly.any(), err


def _backward_level_checked(op: "TraversalOperator", lvl, sigma, depth, omega, delta):
    """:func:`_backward_level` with a transient ABFT ones-checksum lane.

    Same transient-lane contract as :func:`_forward_level_checked`:
    the lane rides only the ``A @ g`` product; δ stays [n, s].
    """
    from repro.kernels.ops import checksum_append, checksum_residual

    omega_col = omega.astype(jnp.float32)[:, None]
    safe_sigma = jnp.where(sigma > 0, sigma, 1.0)
    g = jnp.where(depth == lvl + 1, (1.0 + delta + omega_col) / safe_sigma, 0.0)
    t = op.apply_backward(checksum_append(g))
    err = checksum_residual(t)
    return delta + jnp.where(depth == lvl, sigma * t[:, :-1], 0.0), err


class TraversalOperator:
    """Protocol base: single-device semantics, no collectives."""

    # rows this operator holds (static python int)
    n_rows: int

    # ------------------------------------------------------------- core
    def apply(self, x: jnp.ndarray) -> jnp.ndarray:
        """A @ x for the local rows."""
        raise NotImplementedError

    def apply_backward(self, g: jnp.ndarray) -> jnp.ndarray:
        """A @ g in the dependency sweep (hook for payload-split modes)."""
        return self.apply(g)

    # ------------------------------------------------------ level steps
    def forward_level(self, lvl, sigma, depth):
        """(σ, d) -> (σ', d', local_alive) for one forward level."""
        return _forward_level(self, lvl, sigma, depth)

    def backward_level(self, lvl, sigma, depth, omega, delta):
        """Running δ -> δ' for one dependency level (ω is f32 [n_rows])."""
        return _backward_level(self, lvl, sigma, depth, omega, delta)

    def forward_level_checked(self, lvl, sigma, depth):
        """:meth:`forward_level` + the level's ABFT checksum residual.

        Returns ``(σ', d', local_alive, err)`` where ``err`` is the
        relative column-sum residual of the checksum-extended SpMM (see
        :func:`repro.kernels.ops.checksum_residual`).  The lane is
        transient — state shapes are identical to the unchecked step.
        """
        return _forward_level_checked(self, lvl, sigma, depth)

    def backward_level_checked(self, lvl, sigma, depth, omega, delta):
        """:meth:`backward_level` + the level's ABFT checksum residual."""
        return _backward_level_checked(self, lvl, sigma, depth, omega, delta)

    # ------------------------------------------- collective agreements
    def reduce_any(self, alive: jnp.ndarray) -> jnp.ndarray:
        """Global 'any column discovered a vertex this level'."""
        return alive

    def reduce_max(self, value: jnp.ndarray) -> jnp.ndarray:
        """Global max (depth agreement before the backward sweep)."""
        return value

    def reduce_max_grid(self, value: jnp.ndarray) -> jnp.ndarray:
        """Max over *this traversal's own* devices only.

        Identical to :meth:`reduce_max` except that it never spans
        ``sync_axes``: under a ring overlap policy the loop-bound
        reductions include the sub-cluster replica axis (all replicas run
        max-over-replicas levels so the ppermute rendezvous stays in
        lockstep), but the straggler scheduler
        (:class:`repro.core.driver.BCDriver`) needs each replica's *own*
        data-dependent depth as its per-round cost signal — the quantity
        the synced bound deliberately hides.
        """
        return self.reduce_max(value)

    def reduce_max_sync(self, value: jnp.ndarray) -> jnp.ndarray:
        """Extend an already grid-reduced max over ``sync_axes`` only.

        ``reduce_max == reduce_max_sync ∘ reduce_max_grid``; the driver's
        round body uses the decomposed form so the per-replica depth
        (grid max) and the synced loop bound share one reduction — no
        extra collective when ``sync_axes`` is empty (the common case).
        Identity on single-device operators.
        """
        return value

    def reduce_sum(self, value: jnp.ndarray) -> jnp.ndarray:
        """Global sum of an additive per-column quantity (e.g. n_s)."""
        return value

    # ------------------------------------------------------- geometry
    def row_ids(self) -> jnp.ndarray:
        """Global vertex id of each local row (i32 [n_rows])."""
        return jnp.arange(self.n_rows, dtype=jnp.int32)

    def level_cap(self) -> int:
        """Static upper bound on the number of BFS levels (global n)."""
        return self.n_rows

    def root_omega(self, roots: jnp.ndarray, omega: jnp.ndarray) -> jnp.ndarray:
        """ω at the round's root vertices (f32 [num_roots]; 0 at padding)."""
        safe = jnp.clip(roots, 0, omega.shape[0] - 1)
        return jnp.where(roots >= 0, omega[safe].astype(jnp.float32), 0.0)


class _CallableOperator(TraversalOperator):
    """Adapter: a bare ``A @ x`` closure as a TraversalOperator."""

    def __init__(self, fn: Callable[[jnp.ndarray], jnp.ndarray], n_rows: int | None = None):
        self._fn = fn
        self.n_rows = n_rows if n_rows is not None else -1

    def apply(self, x):
        return self._fn(x)

    def row_ids(self):
        if self.n_rows < 0:
            raise ValueError("callable operator needs n_rows for row_ids()")
        return super().row_ids()


def as_operator(op) -> TraversalOperator:
    """Accept a TraversalOperator or a bare ``A @ x`` callable."""
    if isinstance(op, TraversalOperator):
        return op
    if callable(op):
        return _CallableOperator(op)
    raise TypeError(f"not an operator: {op!r}")


class DenseOperator(TraversalOperator):
    """``A @ x`` with a dense [n, n] 0/1 adjacency (undirected ⇒ symmetric)."""

    def __init__(self, adjacency: jnp.ndarray):
        self.adjacency = adjacency
        self.n_rows = adjacency.shape[0]

    def apply(self, x):
        return self.adjacency.astype(jnp.float32) @ x


class SparseOperator(TraversalOperator):
    """``A @ x`` via arc-list gather + segment-sum.

    ``src``/``dst`` are the padded symmetric arc arrays; padding arcs use
    the sentinel vertex ``n`` on both endpoints, which reads from / writes
    to a discarded extra row.  ``out[v] = Σ_{(u,v) arcs} x[u]``.
    """

    def __init__(self, src: jnp.ndarray, dst: jnp.ndarray, n: int):
        self.src = src
        self.dst = dst
        self.n_rows = n

    def apply(self, x):
        n = self.n_rows
        x_pad = jnp.concatenate([x, jnp.zeros((1,) + x.shape[1:], x.dtype)], axis=0)
        msgs = x_pad[self.src]
        out = jax.ops.segment_sum(msgs, self.dst, num_segments=n + 1)
        return out[:n]


class PallasDenseOperator(TraversalOperator):
    """Fused level kernels on a dense adjacency (single device).

    Overrides the level steps — not ``apply`` — because the kernels fuse
    the frontier mask / g computation and the state update into the
    matmul (see kernels/frontier_spmm.py).  The adjacency may be bf16
    (0/1 values are exact); the accumulator stays f32.
    """

    def __init__(self, adjacency: jnp.ndarray, interpret: bool | None = None):
        self.adjacency = adjacency
        self.n_rows = adjacency.shape[0]
        self.interpret = interpret

    def apply(self, x):  # reference semantics, used by parity tests
        return self.adjacency.astype(jnp.float32) @ x

    def forward_level(self, lvl, sigma, depth):
        from repro.kernels import ops as kops

        sigma2, depth2 = kops.frontier_spmm(
            self.adjacency, sigma, depth, lvl, interpret=self.interpret
        )
        return sigma2, depth2, jnp.any(depth2 != depth)

    def backward_level(self, lvl, sigma, depth, omega, delta):
        from repro.kernels import ops as kops

        return kops.dependency_spmm(
            self.adjacency,
            sigma,
            depth,
            delta,
            omega.astype(jnp.float32),
            lvl,
            interpret=self.interpret,
        )

    # The fused square kernels never expose the raw product t, so the
    # checked steps route through the *partial* kernels instead, with the
    # checksum lane encoded as one extra in-kernel operand column: the
    # kernel recomputes frontier/g from (σ, d, δ, ω), so the lane's
    # operands are chosen to make the recompute land on the column sum —
    # forward σ_c = Σ_j σ_j·[d_j = lvl-1], d_c = lvl-1; backward σ_c = 1,
    # d_c = lvl+1, δ_c = Σ_j g_j - 1 - ω (then g_c = (1+δ_c+ω)/1 = Σ_j g_j).

    def forward_level_checked(self, lvl, sigma, depth):
        from repro.kernels import ops as kops

        fsum = (sigma * (depth == lvl - 1)).sum(axis=1, keepdims=True)
        sg = jnp.concatenate([sigma, fsum], axis=1)
        dp = jnp.concatenate([depth, jnp.full_like(depth[:, :1], lvl - 1)], axis=1)
        t = kops.frontier_spmm_partial(
            self.adjacency, sg, dp, lvl, interpret=self.interpret
        )
        err = kops.checksum_residual(t)
        contrib = t[:, :-1]
        newly = (contrib > 0) & (depth < 0)
        depth2 = jnp.where(newly, lvl, depth)
        sigma2 = sigma + jnp.where(newly, contrib, 0.0)
        return sigma2, depth2, newly.any(), err

    def backward_level_checked(self, lvl, sigma, depth, omega, delta):
        from repro.kernels import ops as kops

        om = omega.astype(jnp.float32)
        safe_sigma = jnp.where(sigma > 0, sigma, 1.0)
        g = jnp.where(depth == lvl + 1, (1.0 + delta + om[:, None]) / safe_sigma, 0.0)
        sg = jnp.concatenate([sigma, jnp.ones_like(sigma[:, :1])], axis=1)
        dp = jnp.concatenate([depth, jnp.full_like(depth[:, :1], lvl + 1)], axis=1)
        dl = jnp.concatenate(
            [delta, g.sum(axis=1, keepdims=True) - 1.0 - om[:, None]], axis=1
        )
        t = kops.dependency_spmm_partial(
            self.adjacency, sg, dp, dl, om, lvl, interpret=self.interpret
        )
        err = kops.checksum_residual(t)
        return delta + jnp.where(depth == lvl, sigma * t[:, :-1], 0.0), err


class DistributedOperator(TraversalOperator):
    """2-D-decomposed operator (paper §3.2) — built *inside* a shard_map
    body, where the mesh axis names are live.

    Per application:
      expand (vertical, Alg. 2 line 15):  all_gather over ``row_axis``
          delivers the frontier slice of grid column j — O(√p) partners.
      local compute (node level):         gather x_col[src_local] +
          segment_sum into dst_local.
      fold (horizontal, Alg. 2 line 19):  psum_scatter over ``col_axis``
          sums the C partials and delivers each device its owned chunk.

    Only frontier-σ / g ever travel; the depth test of the far endpoint
    is folded into the gathered quantity (beyond-paper: one exchange per
    level instead of the paper's σ+d pair).

    ``split_backward`` mimics the paper's unfused σ/d exchange by
    splitting the backward gather into two half-width collectives
    (Fig. 9 benchmark mode).

    ``overlap`` selects the collective schedule (see OVERLAP_POLICIES):
    the ring schedules need the per-row-chunk arc layout
    (:meth:`repro.graphs.partition.TwoDPartition.ring_arcs`) instead of
    the flat ``src_local``/``dst_local`` arrays, because each ring step
    processes only the arcs sourced in the chunk currently in hand.

    ``sync_axes`` lists extra mesh axes whose devices must agree on
    *loop bounds* (liveness / max depth) — the sub-cluster replica axis
    under a ring schedule.  Replicas process different rounds, so their
    level loops have independent data-dependent trip counts; grouped
    collectives (all_gather/psum/psum_scatter) tolerate that, but a
    ``ppermute`` lowers to one collective-permute whose source-target
    pairs span the whole mesh, so every replica must execute the same
    number of ring hops or the runtime deadlocks at the rendezvous.
    Including ``sync_axes`` in ``reduce_any``/``reduce_max`` makes each
    replica run max-over-replicas levels (the extras are masked no-ops);
    per-column *value* reductions (``reduce_sum``) stay grid-local.
    """

    def __init__(
        self,
        src_local: jnp.ndarray | None,  # i32 [max_arcs] — into the gathered column
        dst_local: jnp.ndarray | None,  # i32 [max_arcs] — into the C*chunk partial
        *,
        chunk: int,
        R: int,
        C: int,
        row_axis: str,
        col_axis: str,
        split_backward: bool = False,
        overlap: str = "none",
        ring_src_local: jnp.ndarray | None = None,  # i32 [R, max_ring_arcs]
        ring_dst_local: jnp.ndarray | None = None,  # i32 [R, max_ring_arcs]
        sync_axes: tuple[str, ...] = (),
    ):
        self.src_local = src_local
        self.dst_local = dst_local
        self.ring_src_local = ring_src_local
        self.ring_dst_local = ring_dst_local
        self.chunk = chunk
        self.R = R
        self.C = C
        self.row_axis = row_axis
        self.col_axis = col_axis
        self.grid_axes = (row_axis, col_axis)
        self.sync_axes = tuple(sync_axes)
        self.loop_axes = (row_axis, col_axis) + tuple(sync_axes)
        self.split_backward = split_backward
        self.overlap = normalize_overlap(overlap)
        if self.overlap != "none" and split_backward:
            raise ValueError(
                "split_backward is a barrier-schedule benchmark mode; "
                "it cannot be combined with a ring overlap policy"
            )
        self.n_rows = chunk

    # ---------------------------------------------- collective skeleton
    def _expand(self, x_owned):
        return jax.lax.all_gather(x_owned, self.row_axis, tiled=True)

    def _fold(self, partial):
        return jax.lax.psum_scatter(
            partial, self.col_axis, scatter_dimension=0, tiled=True
        )

    def _local(self, x_col):
        msgs = x_col[self.src_local]  # [max_arcs, s]
        return jax.ops.segment_sum(
            msgs, self.dst_local, num_segments=self.C * self.chunk + 1
        )[: self.C * self.chunk]

    # ------------------------------------------------- ring schedules
    def _fold_partial(self, partial):
        """Fold the [C·chunk, s] partial per the overlap policy."""
        if self.overlap == "expand+fold":
            return self._fold_ring(partial)
        return self._fold(partial)

    def _fold_ring(self, partial):
        """Reduce-ring fold: C-1 ppermute hops over the column axis.

        Block m of ``partial`` (rows [m·chunk, (m+1)·chunk)) is device
        (i, m)'s owned chunk.  The block bound for device j starts at
        device j+1 with that device's local partial and travels the ring
        gathering one add per hop; after C-1 hops device j holds the
        fully summed block j — the exact psum_scatter result, with each
        hop's send overlappable against the neighbouring adds.
        """
        C, chunk = self.C, self.chunk
        if C == 1:
            return partial
        j = jax.lax.axis_index(self.col_axis)
        perm = _ring_perm(C)

        def block(m):  # m is traced: the block this device contributes now
            return jax.lax.dynamic_slice_in_dim(partial, m * chunk, chunk, axis=0)

        acc = block(jnp.mod(j - 1, C))
        for t in range(1, C):
            acc = jax.lax.ppermute(acc, self.col_axis, perm) + block(
                jnp.mod(j - 1 - t, C)
            )
        return acc

    def _ring_partial(self, x_owned):
        """Ring-pipelined expand: R-1 ppermute hops over the row axis.

        The owned chunk rotates around the grid column; at step t the
        chunk of row ``r = (i - t) mod R`` is in hand and exactly its
        arcs (ring slot r) accumulate into the local partial while the
        next chunk is already in flight — the collective-matmul overlap
        of paper Fig. 2, expressed at the arc-list level.
        """
        if self.ring_src_local is None or self.ring_dst_local is None:
            raise ValueError(
                "overlap != 'none' needs the ring arc layout "
                "(TwoDPartition.ring_arcs)"
            )
        R, C, chunk = self.R, self.C, self.chunk
        i = jax.lax.axis_index(self.row_axis)
        perm = _ring_perm(R)
        hand = x_owned
        acc = jnp.zeros((C * chunk + 1,) + x_owned.shape[1:], jnp.float32)
        for t in range(R):
            nxt = jax.lax.ppermute(hand, self.row_axis, perm) if t + 1 < R else None
            r = jnp.mod(i - t, R)
            src_r = jax.lax.dynamic_index_in_dim(self.ring_src_local, r, keepdims=False)
            dst_r = jax.lax.dynamic_index_in_dim(self.ring_dst_local, r, keepdims=False)
            acc = acc + jax.ops.segment_sum(
                hand[src_r], dst_r, num_segments=C * chunk + 1
            )
            if nxt is not None:
                hand = nxt
        return acc[: C * chunk]

    def apply(self, x_owned):
        if self.overlap == "none":
            return self._fold(self._local(self._expand(x_owned)))
        return self._fold_partial(self._ring_partial(x_owned))

    def apply_backward(self, g):
        if not self.split_backward:
            return self.apply(g)
        half = g.shape[1] // 2  # paper-style split payload (benchmark mode)
        return jnp.concatenate([self.apply(g[:, :half]), self.apply(g[:, half:])], axis=1)

    # ------------------------------------------- collective agreements
    def reduce_any(self, alive):
        return jax.lax.psum(alive.astype(jnp.int32), self.loop_axes) > 0

    def reduce_max(self, value):
        return jax.lax.pmax(value, self.loop_axes)

    def reduce_max_grid(self, value):
        # grid-local (never spans sync_axes): the replica's own depth
        return jax.lax.pmax(value, self.grid_axes)

    def reduce_max_sync(self, value):
        # replica-axis extension of a grid max (no-op without sync_axes)
        if not self.sync_axes:
            return value
        return jax.lax.pmax(value, self.sync_axes)

    def reduce_sum(self, value):
        return jax.lax.psum(value, self.grid_axes)

    # ------------------------------------------------------- geometry
    def row_ids(self):
        i = jax.lax.axis_index(self.row_axis)
        j = jax.lax.axis_index(self.col_axis)
        base = (j * self.R + i) * self.chunk  # first owned global vertex id
        return base + jnp.arange(self.chunk, dtype=jnp.int32)

    def level_cap(self):
        return self.chunk * self.R * self.C  # n_pad

    def root_omega(self, roots, omega):
        owned_ids = self.row_ids()
        local = jnp.where(
            roots[None, :] == owned_ids[:, None],
            omega.astype(jnp.float32)[:, None],
            0.0,
        ).sum(axis=0)
        return self.reduce_sum(local)


class DistributedPallasOperator(DistributedOperator):
    """2-D decomposition with fused-Pallas dense-block local compute.

    The device's adjacency block A[rows_i, cols_j] (shape
    [C·chunk, R·chunk]) is dense; block-local compute calls the
    frontier/dependency SpMM kernels in *partial* mode — the operand
    fusion (mask / g recompute in VMEM) is unchanged, the epilogue is
    deferred past the fold because the state update needs the globally
    summed ``t``.  Exchanges therefore carry (σ, d) forward and
    (σ, d, δ, ω) backward — the paper's §3.2 exchange set — instead of
    the pre-masked single tensor of the arc-list operator; the A-stream
    moves to the MXU and may be bf16.

    Under a ring overlap policy the expand rotates the owned operand
    chunks around the row axis with ``ppermute`` and each step multiplies
    the adjacency sub-block ``A[:, r·chunk:(r+1)·chunk]`` against the
    chunk in hand through the partial kernels' chunked-operand mode
    (``acc=`` — the running combine is fused into the kernel's VMEM
    accumulator init), so the next chunk's transfer overlaps the current
    chunk's MXU work.
    """

    def __init__(
        self,
        adjacency_block: jnp.ndarray,  # [C*chunk, R*chunk] dense 0/1 block
        *,
        chunk: int,
        R: int,
        C: int,
        row_axis: str,
        col_axis: str,
        interpret: bool | None = None,
        overlap: str = "none",
        sync_axes: tuple[str, ...] = (),
    ):
        super().__init__(
            src_local=None,
            dst_local=None,
            chunk=chunk,
            R=R,
            C=C,
            row_axis=row_axis,
            col_axis=col_axis,
            overlap=overlap,
            sync_axes=sync_axes,
        )
        self.adjacency_block = adjacency_block
        self.interpret = interpret

    def _local(self, x_col):
        return self.adjacency_block.astype(jnp.float32) @ x_col

    # ------------------------------------------------------ block hooks
    # The dense and blocked-sparse fused operators share the entire level
    # structure below; only how the adjacency block is *represented* (one
    # dense array vs a BCSR tile list) and which kernel consumes it
    # differ.  ``_full_block`` / ``_ring_block`` produce the A-operand
    # (whole block, or the slice for ring step r), the ``_partial_*``
    # hooks dispatch it to the matching kernel.

    def _full_block(self):
        """A-operand of the barrier schedule (the whole device block)."""
        return self.adjacency_block

    def _ring_block(self, r):
        """A-operand of ring step r (columns of the chunk in hand)."""
        return jax.lax.dynamic_slice_in_dim(
            self.adjacency_block, r * self.chunk, self.chunk, axis=1
        )

    def _partial_forward(self, block, sigma, depth, lvl, acc=None):
        from repro.kernels import ops as kops

        return kops.frontier_spmm_partial(
            block, sigma, depth, lvl, acc=acc, interpret=self.interpret
        )

    def _partial_backward(self, block, sigma, depth, delta, omega, lvl, acc=None):
        from repro.kernels import ops as kops

        return kops.dependency_spmm_partial(
            block, sigma, depth, delta, omega, lvl, acc=acc, interpret=self.interpret
        )

    def _ring_steps(self, operands, step_fn):
        """Ring-pipelined expand over the row axis (block form).

        ``operands`` is a tuple of owned [chunk, ...] arrays that travel
        together; ``step_fn(block, hand, acc)`` folds one chunk's product
        into the running [C·chunk, s] accumulator, ``block`` being
        ``self._ring_block(r)`` for the chunk in hand.  The ppermute for
        step t+1 is issued before step t's compute so XLA's async
        collective-permute overlaps the transfer with the block compute.
        """
        R, chunk = self.R, self.chunk
        i = jax.lax.axis_index(self.row_axis)
        perm = _ring_perm(R)
        hand = tuple(operands)
        acc = jnp.zeros((self.C * chunk, operands[0].shape[1]), jnp.float32)
        for t in range(R):
            nxt = (
                tuple(jax.lax.ppermute(x, self.row_axis, perm) for x in hand)
                if t + 1 < R
                else None
            )
            r = jnp.mod(i - t, R)
            acc = step_fn(self._ring_block(r), hand, acc)
            if nxt is not None:
                hand = nxt
        return acc

    def _ring_partial(self, x_owned):
        # dense-block counterpart of the arc-list ring (used via apply)
        return self._ring_steps(
            (x_owned,), lambda a_r, hand, acc: acc + a_r.astype(jnp.float32) @ hand[0]
        )

    def forward_level(self, lvl, sigma, depth):
        if self.overlap == "none":
            sigma_col = self._expand(sigma)  # [R*chunk, s]
            depth_col = self._expand(depth)
            partial = self._partial_forward(
                self._full_block(), sigma_col, depth_col, lvl
            )  # [C*chunk, s]
        else:
            partial = self._ring_steps(
                (sigma, depth),
                lambda blk, hand, acc: self._partial_forward(
                    blk, hand[0], hand[1], lvl, acc=acc
                ),
            )
        t = self._fold_partial(partial)  # [chunk, s]
        newly = (t > 0) & (depth < 0)
        depth = jnp.where(newly, lvl, depth)
        sigma = sigma + jnp.where(newly, t, 0.0)
        return sigma, depth, newly.any()

    def backward_level(self, lvl, sigma, depth, omega, delta):
        omega_f = omega.astype(jnp.float32)
        if self.overlap == "none":
            sigma_col = self._expand(sigma)
            depth_col = self._expand(depth)
            delta_col = self._expand(delta)
            omega_col = self._expand(omega_f)
            partial = self._partial_backward(
                self._full_block(), sigma_col, depth_col, delta_col, omega_col, lvl
            )
        else:
            partial = self._ring_steps(
                (sigma, depth, delta, omega_f),
                lambda blk, hand, acc: self._partial_backward(
                    blk, hand[0], hand[1], hand[2], hand[3], lvl, acc=acc
                ),
            )
        t = self._fold_partial(partial)
        return delta + jnp.where(depth == lvl, sigma * t, 0.0)

    # Checked level steps: same extend-operand trick as the single-device
    # Pallas operator (the kernels recompute frontier/g in VMEM, so the
    # checksum lane is encoded in the operands), threaded through the
    # identical expand/ring + fold structure — the lane column survives
    # all_gather / ppermute / psum_scatter because each is linear per
    # column, so one residual on the folded t audits the whole pipeline.
    # The sparse and hybrid subclasses inherit these via the block hooks.

    def forward_level_checked(self, lvl, sigma, depth):
        from repro.kernels import ops as kops

        fsum = (sigma * (depth == lvl - 1)).sum(axis=1, keepdims=True)
        sg = jnp.concatenate([sigma, fsum], axis=1)
        dp = jnp.concatenate([depth, jnp.full_like(depth[:, :1], lvl - 1)], axis=1)
        if self.overlap == "none":
            partial = self._partial_forward(
                self._full_block(), self._expand(sg), self._expand(dp), lvl
            )
        else:
            partial = self._ring_steps(
                (sg, dp),
                lambda blk, hand, acc: self._partial_forward(
                    blk, hand[0], hand[1], lvl, acc=acc
                ),
            )
        t = self._fold_partial(partial)
        err = kops.checksum_residual(t)
        contrib = t[:, :-1]
        newly = (contrib > 0) & (depth < 0)
        depth2 = jnp.where(newly, lvl, depth)
        sigma2 = sigma + jnp.where(newly, contrib, 0.0)
        return sigma2, depth2, newly.any(), err

    def backward_level_checked(self, lvl, sigma, depth, omega, delta):
        from repro.kernels import ops as kops

        omega_f = omega.astype(jnp.float32)
        safe_sigma = jnp.where(sigma > 0, sigma, 1.0)
        g = jnp.where(
            depth == lvl + 1, (1.0 + delta + omega_f[:, None]) / safe_sigma, 0.0
        )
        sg = jnp.concatenate([sigma, jnp.ones_like(sigma[:, :1])], axis=1)
        dp = jnp.concatenate([depth, jnp.full_like(depth[:, :1], lvl + 1)], axis=1)
        dl = jnp.concatenate(
            [delta, g.sum(axis=1, keepdims=True) - 1.0 - omega_f[:, None]], axis=1
        )
        if self.overlap == "none":
            partial = self._partial_backward(
                self._full_block(),
                self._expand(sg),
                self._expand(dp),
                self._expand(dl),
                self._expand(omega_f),
                lvl,
            )
        else:
            partial = self._ring_steps(
                (sg, dp, dl, omega_f),
                lambda blk, hand, acc: self._partial_backward(
                    blk, hand[0], hand[1], hand[2], hand[3], lvl, acc=acc
                ),
            )
        t = self._fold_partial(partial)
        err = kops.checksum_residual(t)
        return delta + jnp.where(depth == lvl, sigma * t[:, :-1], 0.0), err


class DistributedPallasSparseOperator(DistributedPallasOperator):
    """2-D decomposition with blocked-sparse (BCSR) fused local compute.

    Same level structure as :class:`DistributedPallasOperator`, but the
    device's adjacency block is a tile list — only the nonzero (bm × bk)
    tiles of A[rows_i, cols_j] are stored (``tiles`` [T, bm, bk] +
    per-tile ``tile_rows``/``tile_cols`` index maps, host-built once by
    :meth:`repro.graphs.partition.TwoDPartition.blocked_sparse`) — and
    the local compute runs the scalar-prefetched sparse kernels
    (kernels/blocked_spmm.py), so per-device adjacency memory and
    A-stream HBM traffic are O(nnz_tiles · bm · bk) instead of
    O(n_pad²/p).  This is the engine for the RMAT-scale regime where the
    dense block does not fit.

    Under a ring overlap policy the per-ring-chunk tile slices
    (``ring_*`` [R, Tr, ...]; slot r = the tiles sourced in the chunk of
    grid row r, column ids re-based to the chunk) are selected by
    ``dynamic_index_in_dim`` at each hop — the exact sparse counterpart
    of the dense engine's ``dynamic_slice`` — and the chunked-``acc``
    kernel mode carries the running partial between hops.
    """

    def __init__(
        self,
        tiles: jnp.ndarray | None = None,  # [T, bm, bk] stored tiles
        tile_rows: jnp.ndarray | None = None,  # i32 [T]
        tile_cols: jnp.ndarray | None = None,  # i32 [T]
        *,
        chunk: int,
        R: int,
        C: int,
        row_axis: str,
        col_axis: str,
        interpret: bool | None = None,
        overlap: str = "none",
        sync_axes: tuple[str, ...] = (),
        ring_tiles: jnp.ndarray | None = None,  # [R, Tr, bm, bk]
        ring_tile_rows: jnp.ndarray | None = None,  # i32 [R, Tr]
        ring_tile_cols: jnp.ndarray | None = None,  # i32 [R, Tr]
    ):
        super().__init__(
            None,
            chunk=chunk,
            R=R,
            C=C,
            row_axis=row_axis,
            col_axis=col_axis,
            interpret=interpret,
            overlap=overlap,
            sync_axes=sync_axes,
        )
        if self.overlap == "none" and tiles is None:
            raise ValueError("barrier schedule needs the full tile layout")
        if self.overlap != "none" and ring_tiles is None:
            raise ValueError(
                "overlap != 'none' needs the ring tile layout "
                "(TwoDPartition.blocked_sparse(ring=True))"
            )
        self.tiles = tiles
        self.tile_rows = tile_rows
        self.tile_cols = tile_cols
        self.ring_tiles = ring_tiles
        self.ring_tile_rows = ring_tile_rows
        self.ring_tile_cols = ring_tile_cols

    # ------------------------------------------------------ block hooks
    def _full_block(self):
        return (self.tiles, self.tile_rows, self.tile_cols)

    def _ring_block(self, r):
        pick = lambda a: jax.lax.dynamic_index_in_dim(a, r, keepdims=False)
        return (
            pick(self.ring_tiles),
            pick(self.ring_tile_rows),
            pick(self.ring_tile_cols),
        )

    def _partial_forward(self, block, sigma, depth, lvl, acc=None):
        from repro.kernels import ops as kops

        tiles, rows, cols = block
        return kops.frontier_spmm_sparse(
            tiles, rows, cols, sigma, depth, lvl,
            m=self.C * self.chunk, acc=acc, interpret=self.interpret,
        )

    def _partial_backward(self, block, sigma, depth, delta, omega, lvl, acc=None):
        from repro.kernels import ops as kops

        tiles, rows, cols = block
        return kops.dependency_spmm_sparse(
            tiles, rows, cols, sigma, depth, delta, omega, lvl,
            m=self.C * self.chunk, acc=acc, interpret=self.interpret,
        )

    # --------------------------------------- reference apply() semantics
    def _dense_of(self, block, kdim):
        from repro.kernels.blocked_spmm import tiles_to_dense

        tiles, rows, cols = block
        return tiles_to_dense(tiles, rows, cols, self.C * self.chunk, kdim)

    def _local(self, x_col):
        # parity/debug path only — the engine runs the fused level hooks
        return self._dense_of(self._full_block(), x_col.shape[0]) @ x_col

    def _ring_partial(self, x_owned):
        return self._ring_steps(
            (x_owned,),
            lambda blk, hand, acc: acc + self._dense_of(blk, self.chunk) @ hand[0],
        )


class DistributedPallasHybridOperator(DistributedPallasSparseOperator):
    """2-D decomposition with a per-cell dense/BCSR kernel choice.

    Each device cell carries BOTH operand sets (shard_map needs uniform
    shapes across the mesh) but only its chosen one holds data: the host
    layout (:meth:`repro.graphs.partition.TwoDPartition.blocked_hybrid`)
    materializes dense block data for the dense-chosen cells and tile
    data for the sparse-chosen cells (the other slot is untouched
    zeros / the minimal filler list).  ``dense_cell`` is this device's
    choice — a *traced* scalar, so one SPMD program serves the whole
    mesh and each cell branches locally with ``lax.cond``; the branch
    contains only block-local kernel work (never a collective), so the
    mixed mesh stays in lockstep through every overlap policy's
    collective schedule, which this class inherits unchanged through the
    ``_full_block`` / ``_ring_block`` / ``_partial_*`` seams.
    """

    def __init__(
        self,
        adjacency_block: jnp.ndarray,  # [C*chunk, R*chunk] dense data (or zeros)
        dense_cell: jnp.ndarray,  # scalar bool: this cell's kernel choice
        **kwargs,
    ):
        super().__init__(**kwargs)
        self.adjacency_block = adjacency_block
        self.dense_cell = dense_cell

    # ------------------------------------------------------ block hooks
    def _full_block(self):
        return (self.adjacency_block,) + super()._full_block()

    def _ring_block(self, r):
        dense_r = jax.lax.dynamic_slice_in_dim(
            self.adjacency_block, r * self.chunk, self.chunk, axis=1
        )
        return (dense_r,) + super()._ring_block(r)

    def _partial_forward(self, block, sigma, depth, lvl, acc=None):
        from repro.kernels import ops as kops

        a_dense, tiles, rows, cols = block
        return jax.lax.cond(
            self.dense_cell,
            lambda: kops.frontier_spmm_partial(
                a_dense, sigma, depth, lvl, acc=acc, interpret=self.interpret
            ),
            lambda: kops.frontier_spmm_sparse(
                tiles, rows, cols, sigma, depth, lvl,
                m=self.C * self.chunk, acc=acc, interpret=self.interpret,
            ),
        )

    def _partial_backward(self, block, sigma, depth, delta, omega, lvl, acc=None):
        from repro.kernels import ops as kops

        a_dense, tiles, rows, cols = block
        return jax.lax.cond(
            self.dense_cell,
            lambda: kops.dependency_spmm_partial(
                a_dense, sigma, depth, delta, omega, lvl,
                acc=acc, interpret=self.interpret,
            ),
            lambda: kops.dependency_spmm_sparse(
                tiles, rows, cols, sigma, depth, delta, omega, lvl,
                m=self.C * self.chunk, acc=acc, interpret=self.interpret,
            ),
        )

    # --------------------------------------- reference apply() semantics
    def _mixed_dense(self, block, kdim):
        """Dense view of whichever representation this cell holds data in."""
        a_dense, *tile_block = block
        return jnp.where(
            self.dense_cell,
            a_dense.astype(jnp.float32),
            self._dense_of(tuple(tile_block), kdim),
        )

    def _local(self, x_col):
        # parity/debug path only — the engine runs the fused level hooks
        return self._mixed_dense(self._full_block(), x_col.shape[0]) @ x_col

    def _ring_partial(self, x_owned):
        return self._ring_steps(
            (x_owned,),
            lambda blk, hand, acc: acc + self._mixed_dense(blk, self.chunk) @ hand[0],
        )


# --------------------------------------------------------------------------
# Weighted traversal (delta-stepping buckets, Fan et al. arXiv:1701.05975)
# --------------------------------------------------------------------------
#
# The weighted operators deliberately ship *no* new Pallas kernels: the
# bucket recurrences are equality-masked min-plus / sum-product contractions
# that XLA already fuses well at the block sizes the fake-device CI exercises,
# and on TPU the dense variants still land on the MXU/VPU through the same
# [m, k, s] contraction shapes as the unweighted partial kernels.  Fusing the
# relax/sigma/delta steps into VMEM-resident Pallas kernels (the weighted
# analogue of kernels/frontier_spmm.py) is the follow-up once real-TPU
# profiles exist.  Every engine kind therefore accepts ``weighted=`` today;
# pallas/pallas_bf16/pallas_sparse/pallas_hybrid run their weighted compute
# on float32 operands (weights are never cast to bf16 — distances feed exact
# equality masks).

_BIG_DIST = 1e30  # segment_min identity guard: anything above is "unreached"


def auto_delta(graph) -> float:
    """Derive a bucket width from edge-weight statistics (host-side).

    The classic delta-stepping guidance is Δ ≈ Θ(1 / max-degree) scaled by
    the mean weight — wide enough that a bucket amortizes a relaxation
    sweep, narrow enough that the light-edge fixpoint stays shallow.  We
    clamp below by the minimum weight so a bucket always makes progress.
    Deterministic in the graph (no RNG): the same graph always yields the
    same Δ, which the reproducibility tests rely on.
    """
    w = getattr(graph, "w", None)
    if w is None or w.size == 0:
        raise ValueError("auto_delta needs a weighted graph with at least one edge")
    avg_degree = max(1.0, float(graph.num_arcs) / float(max(1, graph.n)))
    return float(max(float(w.min()), float(w.mean()) / avg_degree))


def _bucket_split(w, delta, heavy: bool):
    """Per-arc weight with non-selected arcs pushed to +inf.

    Arcs with w <= delta are *light* (relaxed to a fixpoint inside the
    bucket), w > delta are *heavy* (relaxed once after the bucket
    settles).  Padding arcs carry w == 0 and are excluded from both.
    """
    if heavy:
        sel = w > delta
    else:
        sel = (w > 0) & (w <= delta)
    return jnp.where(sel, w, jnp.inf)


class WeightedTraversalOperator(TraversalOperator):
    """Single-device weighted operator base: bucket-loop protocol.

    The engine's bucket loops (:func:`repro.core.engine.forward_buckets`,
    :func:`~repro.core.engine.backward_buckets`) drive three data hooks —

      relax(dist, frontier, heavy)  tentative-distance relaxation: the
          min over selected arcs (u, v) with u in the frontier of
          ``dist[u] + w``; +inf where no arc relaxes v.
      sigma_step(sigma_in, dist)    σ'_v = Σ_{u : d_v = d_u + w} σ_in[u]
          (shortest-path predecessor counting via the distance-equality
          mask; overwrite semantics — the engine fixpoints it over the
          within-bucket predecessor DAG).
      delta_step(g, dist)           per-vertex Σ_{v : d_v = d_u + w} g[v]
          (the dependency sum over *successors*; the engine multiplies by
          σ_u and fixpoints within the bucket).

    — plus ``reduce_min`` for the bucket-skip agreement.  All reductions
    are identities on a single device.
    """

    weighted = True

    def __init__(self, delta: float):
        delta = float(delta)
        if not (delta > 0.0) or not math.isfinite(delta):
            raise ValueError(f"bucket width delta must be positive and finite, got {delta}")
        self.delta = delta

    def reduce_min(self, value):
        return value

    def relax(self, dist, frontier, heavy):  # pragma: no cover - interface
        raise NotImplementedError

    def sigma_step(self, sigma_in, dist):  # pragma: no cover - interface
        raise NotImplementedError

    def delta_step(self, g, dist):  # pragma: no cover - interface
        raise NotImplementedError


class WeightedDenseOperator(WeightedTraversalOperator):
    """[n, n] weight-matrix operator (weight 0 encodes "no edge").

    The relax step is a min-plus contraction, sigma/delta are
    equality-masked sum contractions — all [n, n, s] broadcasts, the
    weighted analogue of the dense matmul path (small n only, like
    :class:`DenseOperator`).
    """

    def __init__(self, weights: jnp.ndarray, delta: float):
        super().__init__(delta)
        self.weights = weights.astype(jnp.float32)
        self.n_rows = weights.shape[0]
        self.mask = self.weights > 0
        self.w_light = _bucket_split(self.weights, self.delta, heavy=False)
        self.w_heavy = _bucket_split(self.weights, self.delta, heavy=True)
        self.w_full = jnp.where(self.mask, self.weights, jnp.inf)

    def apply(self, x):
        # unweighted reachability semantics (parity/debug only)
        return self.mask.astype(jnp.float32) @ x

    def relax(self, dist, frontier, heavy):
        wsel = self.w_heavy if heavy else self.w_light
        d = jnp.where(frontier, dist, jnp.inf)
        # cand[v, s] = min_u d[u, s] + w[u, v]
        return jnp.min(d[:, None, :] + wsel[:, :, None], axis=0)

    def _eq(self, dist):
        # eq[u, v, s]: arc (u, v) lies on a shortest path into v
        cand = dist[:, None, :] + self.w_full[:, :, None]
        return self.mask[:, :, None] & jnp.isfinite(cand) & (dist[None, :, :] == cand)

    def sigma_step(self, sigma_in, dist):
        # dot_general over u (same contraction the unweighted matmul uses,
        # so unit weights at delta=1 reproduce DenseOperator bitwise)
        eq = self._eq(dist).astype(jnp.float32)
        return jnp.einsum("uvs,us->vs", eq, sigma_in)

    def delta_step(self, g, dist):
        eq = self._eq(dist).astype(jnp.float32)
        return jnp.einsum("uvs,vs->us", eq, g)


class WeightedSparseOperator(WeightedTraversalOperator):
    """Padded-arc-list weighted operator (gather + segment_min/sum).

    Sentinel arcs point at vertex slot ``n`` with weight 0; every
    accumulation allocates n+1 segments and discards the sentinel row,
    exactly like :class:`SparseOperator`.
    """

    def __init__(self, src, dst, w, n: int, delta: float):
        super().__init__(delta)
        self.src = src
        self.dst = dst
        self.w = w.astype(jnp.float32)
        self.n = n
        self.n_rows = n
        self.w_light = _bucket_split(self.w, self.delta, heavy=False)
        self.w_heavy = _bucket_split(self.w, self.delta, heavy=True)
        self.w_full = jnp.where(self.w > 0, self.w, jnp.inf)

    def apply(self, x):
        x_pad = jnp.concatenate([x, jnp.zeros((1,) + x.shape[1:], x.dtype)], axis=0)
        msgs = x_pad[self.src]
        return jax.ops.segment_sum(msgs, self.dst, num_segments=self.n + 1)[: self.n]

    def _pad(self, x, fill):
        return jnp.concatenate([x, jnp.full((1,) + x.shape[1:], fill, x.dtype)], axis=0)

    def relax(self, dist, frontier, heavy):
        wsel = self.w_heavy if heavy else self.w_light
        d_pad = self._pad(jnp.where(frontier, dist, jnp.inf), jnp.inf)
        val = d_pad[self.src] + wsel[:, None]
        cand = jax.ops.segment_min(val, self.dst, num_segments=self.n + 1)[: self.n]
        return jnp.where(cand > _BIG_DIST, jnp.inf, cand)

    def _eq(self, dist):
        d_pad = self._pad(dist, jnp.inf)
        cand = d_pad[self.src] + self.w_full[:, None]
        return jnp.isfinite(cand) & (d_pad[self.dst] == cand), d_pad

    def sigma_step(self, sigma_in, dist):
        eq, _ = self._eq(dist)
        s_pad = self._pad(sigma_in, 0.0)
        contrib = jnp.where(eq, s_pad[self.src], 0.0)
        return jax.ops.segment_sum(contrib, self.dst, num_segments=self.n + 1)[: self.n]

    def delta_step(self, g, dist):
        # successor test from the dst side: the symmetric arc list serves
        # both directions, so accumulate g over arcs (y, x) with
        # d_y = d_x + w into x
        d_pad = self._pad(dist, jnp.inf)
        cand = d_pad[self.dst] + self.w_full[:, None]
        eq = jnp.isfinite(cand) & (d_pad[self.src] == cand)
        g_pad = self._pad(g, 0.0)
        contrib = jnp.where(eq, g_pad[self.src], 0.0)
        return jax.ops.segment_sum(contrib, self.dst, num_segments=self.n + 1)[: self.n]


class DistributedWeightedOperator(DistributedOperator):
    """2-D-decomposed weighted operator, arc-list local compute.

    Collective skeleton per relax: expand the frontier's (masked)
    distances over ``row_axis`` (all_gather), per-arc min-plus into the
    [C·chunk] partial (segment_min), then a *min-fold*: ``pmin`` over
    ``col_axis`` followed by slicing the device's owned chunk — the
    min-plus analogue of the psum_scatter fold.  sigma/delta steps are
    equality-masked segment sums folded with the usual psum_scatter; the
    equality test needs the *output-side* distances, replicated with an
    all_gather over ``col_axis`` (fold-order blocks, matching
    ``dst_local``'s partial indexing).

    Always the barrier schedule internally (ring-pipelining bucketed
    relaxation is future work); ``sync_axes`` still applies so replicas
    stay in loop-bound lockstep on sub-cluster meshes.

    weighted = True
    """

    weighted = True

    def __init__(
        self,
        src_local,
        dst_local,
        w_local,
        *,
        delta: float,
        chunk: int,
        R: int,
        C: int,
        row_axis: str,
        col_axis: str,
        sync_axes: tuple[str, ...] = (),
    ):
        super().__init__(
            src_local,
            dst_local,
            chunk=chunk,
            R=R,
            C=C,
            row_axis=row_axis,
            col_axis=col_axis,
            overlap="none",
            sync_axes=sync_axes,
        )
        if not (delta > 0):
            raise ValueError(f"bucket width delta must be positive, got {delta}")
        self.delta = float(delta)
        self.w_local = w_local.astype(jnp.float32)
        self.w_light = _bucket_split(self.w_local, self.delta, heavy=False)
        self.w_heavy = _bucket_split(self.w_local, self.delta, heavy=True)
        self.w_full = jnp.where(self.w_local > 0, self.w_local, jnp.inf)

    # ------------------------------------------------ collective pieces
    def _expand_out(self, x_owned):
        """Replicate owned chunks along the *fold* dimension: [chunk, s]
        -> [C·chunk, s] with block j holding device (i, j)'s chunk — the
        layout ``dst_local`` indexes (psum_scatter's scatter order)."""
        return jax.lax.all_gather(x_owned, self.col_axis, tiled=True)

    def _min_fold(self, partial):
        """Elementwise-min fold of the [C·chunk, s] partial: pmin over the
        column axis, then slice the owned block."""
        folded = jax.lax.pmin(partial, self.col_axis)
        j = jax.lax.axis_index(self.col_axis)
        return jax.lax.dynamic_slice_in_dim(folded, j * self.chunk, self.chunk, axis=0)

    def reduce_min(self, value):
        return jax.lax.pmin(value, self.loop_axes)

    # ------------------------------------------------------ bucket hooks
    def relax(self, dist, frontier, heavy):
        wsel = self.w_heavy if heavy else self.w_light
        d_col = self._expand(jnp.where(frontier, dist, jnp.inf))  # [R*chunk, s]
        val = d_col[self.src_local] + wsel[:, None]
        partial = jax.ops.segment_min(
            val, self.dst_local, num_segments=self.C * self.chunk + 1
        )[: self.C * self.chunk]
        partial = jnp.where(partial > _BIG_DIST, jnp.inf, partial)
        return self._min_fold(partial)

    def _pad_out(self, x_out, fill):
        return jnp.concatenate(
            [x_out, jnp.full((1,) + x_out.shape[1:], fill, x_out.dtype)], axis=0
        )

    def sigma_step(self, sigma_in, dist):
        s_col = self._expand(sigma_in)
        d_col = self._expand(dist)
        d_out = self._pad_out(self._expand_out(dist), jnp.inf)
        cand = d_col[self.src_local] + self.w_full[:, None]
        eq = jnp.isfinite(cand) & (d_out[self.dst_local] == cand)
        contrib = jnp.where(eq, s_col[self.src_local], 0.0)
        partial = jax.ops.segment_sum(
            contrib, self.dst_local, num_segments=self.C * self.chunk + 1
        )[: self.C * self.chunk]
        return self._fold(partial)

    def delta_step(self, g, dist):
        g_col = self._expand(g)
        d_col = self._expand(dist)
        d_out = self._pad_out(self._expand_out(dist), jnp.inf)
        cand = d_out[self.dst_local] + self.w_full[:, None]
        eq = jnp.isfinite(cand) & (d_col[self.src_local] == cand)
        contrib = jnp.where(eq, g_col[self.src_local], 0.0)
        partial = jax.ops.segment_sum(
            contrib, self.dst_local, num_segments=self.C * self.chunk + 1
        )[: self.C * self.chunk]
        return self._fold(partial)


class DistributedWeightedDenseOperator(DistributedOperator):
    """2-D-decomposed weighted operator on a dense weight block.

    The device holds W[rows_i, cols_j] as [C·chunk, R·chunk] float32
    (weight 0 = no edge) — the weighted analogue of
    :class:`DistributedPallasOperator`'s adjacency block; the engine
    kinds pallas / pallas_bf16 / pallas_sparse / pallas_hybrid all route
    their weighted compute through this operator (BCSR/hybrid layouts
    are densified per device cell inside the shard_map body — see
    ``repro.core.distributed``).  Compute is XLA [m, k, s] contractions;
    fused Pallas bucket kernels are the documented follow-up.

    weighted = True
    """

    weighted = True

    def __init__(
        self,
        weight_block,
        *,
        delta: float,
        chunk: int,
        R: int,
        C: int,
        row_axis: str,
        col_axis: str,
        sync_axes: tuple[str, ...] = (),
    ):
        super().__init__(
            None,
            None,
            chunk=chunk,
            R=R,
            C=C,
            row_axis=row_axis,
            col_axis=col_axis,
            overlap="none",
            sync_axes=sync_axes,
        )
        if not (delta > 0):
            raise ValueError(f"bucket width delta must be positive, got {delta}")
        self.delta = float(delta)
        self.weight_block = weight_block.astype(jnp.float32)  # [C*chunk, R*chunk]
        self.mask = self.weight_block > 0
        self.w_light = _bucket_split(self.weight_block, self.delta, heavy=False)
        self.w_heavy = _bucket_split(self.weight_block, self.delta, heavy=True)
        self.w_full = jnp.where(self.mask, self.weight_block, jnp.inf)

    def _expand_out(self, x_owned):
        return jax.lax.all_gather(x_owned, self.col_axis, tiled=True)

    def _min_fold(self, partial):
        folded = jax.lax.pmin(partial, self.col_axis)
        j = jax.lax.axis_index(self.col_axis)
        return jax.lax.dynamic_slice_in_dim(folded, j * self.chunk, self.chunk, axis=0)

    def reduce_min(self, value):
        return jax.lax.pmin(value, self.loop_axes)

    def _local(self, x_col):
        # unweighted reachability semantics (parity/debug only)
        return self.mask.astype(jnp.float32) @ x_col

    def relax(self, dist, frontier, heavy):
        wsel = self.w_heavy if heavy else self.w_light
        d_col = self._expand(jnp.where(frontier, dist, jnp.inf))  # [k, s]
        partial = jnp.min(wsel[:, :, None] + d_col[None, :, :], axis=1)  # [m, s]
        return self._min_fold(partial)

    def sigma_step(self, sigma_in, dist):
        s_col = self._expand(sigma_in)
        d_col = self._expand(dist)
        d_out = self._expand_out(dist)  # [m, s]
        cand = d_col[None, :, :] + self.w_full[:, :, None]  # [m, k, s]
        eq = self.mask[:, :, None] & jnp.isfinite(cand) & (d_out[:, None, :] == cand)
        partial = jnp.sum(jnp.where(eq, s_col[None, :, :], 0.0), axis=1)
        return self._fold(partial)

    def delta_step(self, g, dist):
        g_col = self._expand(g)
        d_col = self._expand(dist)
        d_out = self._expand_out(dist)
        cand = d_out[:, None, :] + self.w_full[:, :, None]
        eq = self.mask[:, :, None] & jnp.isfinite(cand) & (d_col[None, :, :] == cand)
        partial = jnp.sum(jnp.where(eq, g_col[None, :, :], 0.0), axis=1)
        return self._fold(partial)
