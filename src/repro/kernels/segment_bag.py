"""Pallas TPU kernel: EmbeddingBag (gather + segment-reduce).

JAX has no native ``nn.EmbeddingBag``; the recsys (DLRM) and GNN paths
build it from ``jnp.take`` + ``segment_sum``.  On TPU the XLA lowering
materializes the gathered [B, L, D] tensor in HBM; this kernel instead
streams one table row per grid step straight into a VMEM accumulator —
HBM traffic drops from (B·L·D reads + B·L·D writes + B·D) to
(B·L·D reads + B·D writes).

The row id is *scalar-prefetched* (`PrefetchScalarGridSpec`): the
BlockSpec index_map picks the table block to DMA based on the indices
array, which is the TPU-idiomatic form of data-dependent gathering
(same machinery as paged attention block tables).

Grid = (B, D/bd, L); the output block (1, bd) stays VMEM-resident across
the L innermost steps (its index_map ignores ``l``), so the reduction
never touches HBM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["segment_bag_kernel", "segment_bag_pallas"]


def segment_bag_kernel(idx_ref, row_ref, weight_ref, out_ref):
    b = pl.program_id(0)
    l = pl.program_id(2)

    @pl.when(l == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    valid = idx_ref[b, l] >= 0
    w = jnp.where(valid, weight_ref[0, 0], 0.0)
    out_ref[...] += w * row_ref[...].astype(jnp.float32)


def segment_bag_pallas(
    table: jnp.ndarray,
    indices: jnp.ndarray,
    weights: jnp.ndarray | None = None,
    *,
    bd: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Raw pallas_call; D must be a multiple of bd (see ops.py).

    Args:
      table:   [V, D] embedding table (f32/bf16).
      indices: i32 [B, L]; -1 entries are padding.
      weights: optional f32 [B, L] per-sample weights.
    """
    V, D = table.shape
    B, L = indices.shape
    assert D % bd == 0, (D, bd)
    if weights is None:
        weights = jnp.ones((B, L), jnp.float32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, D // bd, L),
        in_specs=[
            # table row chosen by the prefetched index (clamped; padding
            # rows are zero-weighted in the kernel body)
            pl.BlockSpec(
                (1, bd), lambda b, j, l, idx_ref: (jnp.maximum(idx_ref[b, l], 0), j)
            ),
            pl.BlockSpec((1, 1), lambda b, j, l, idx_ref: (b, l)),  # weight
        ],
        out_specs=pl.BlockSpec((1, bd), lambda b, j, l, idx_ref: (b, j)),
    )
    return pl.pallas_call(
        segment_bag_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), jnp.float32),
        interpret=interpret,
    )(indices, table, weights)
