"""Pallas TPU kernel: fused backward dependency level.

Per level of MGBC's dependency accumulation (checking successors):

    g   = (1 + δ + ω) / σ   on  d == lvl+1   (0 elsewhere)
    t   = A @ g
    δ' += σ ⊙ t             on  d == lvl

As with the forward kernel, the operand ``g`` is recomputed from the
(σ, d, δ, ω) tiles inside the matmul loop instead of being materialized
in HBM, and the δ update is fused into the epilogue.  This mirrors the
paper's "reuse the forward prefix-sum in the backward sweep": the level
structure (d) streams through VMEM once per level with no auxiliary
offset arrays.

Grid and tiling identical to frontier_spmm (ω broadcast along s is an
extra [bk, 1] tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "dependency_spmm_kernel",
    "dependency_spmm_pallas",
    "dependency_partial_kernel",
    "dependency_partial_acc_kernel",
    "dependency_partial_pallas",
]


def dependency_spmm_kernel(
    lvl_ref,  # (1,1) i32
    a_ref,  # [bm, bk]
    sigma_k_ref,  # [bk, bs]
    depth_k_ref,  # [bk, bs]
    delta_k_ref,  # [bk, bs]
    omega_k_ref,  # [bk, 1]
    sigma_io_ref,  # [bm, bs]
    depth_io_ref,  # [bm, bs]
    delta_io_ref,  # [bm, bs]
    delta_out_ref,  # [bm, bs]
    acc_ref,  # VMEM [bm, bs] f32
    *,
    k_steps: int,
):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    lvl = lvl_ref[0, 0]
    sigma_k = sigma_k_ref[...]
    safe_sigma = jnp.where(sigma_k > 0, sigma_k, 1.0)
    g = jnp.where(
        depth_k_ref[...] == lvl + 1,
        (1.0 + delta_k_ref[...] + omega_k_ref[...]) / safe_sigma,
        0.0,
    )
    acc_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.float32), g, preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _epilogue():
        t = acc_ref[...]
        keep = depth_io_ref[...] == lvl
        delta_out_ref[...] = delta_io_ref[...] + jnp.where(
            keep, sigma_io_ref[...] * t, 0.0
        )


def dependency_spmm_pallas(
    adjacency: jnp.ndarray,
    sigma: jnp.ndarray,
    depth: jnp.ndarray,
    delta: jnp.ndarray,
    omega: jnp.ndarray,
    lvl: jnp.ndarray,
    *,
    bm: int = 128,
    bk: int = 128,
    bs: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Raw pallas_call; block-aligned shapes required (see ops.py)."""
    n, _ = adjacency.shape
    _, s = sigma.shape
    assert n % bm == 0 and n % bk == 0 and s % bs == 0, (n, s, bm, bk, bs)
    k_steps = n // bk
    grid = (n // bm, s // bs, k_steps)

    lvl_arr = jnp.asarray(lvl, jnp.int32).reshape(1, 1)
    omega_col = omega.astype(jnp.float32).reshape(n, 1)
    kernel = functools.partial(dependency_spmm_kernel, k_steps=k_steps)

    from jax.experimental.pallas import tpu as pltpu

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),  # lvl
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),  # A
            pl.BlockSpec((bk, bs), lambda i, j, k: (k, j)),  # σ (contraction)
            pl.BlockSpec((bk, bs), lambda i, j, k: (k, j)),  # d (contraction)
            pl.BlockSpec((bk, bs), lambda i, j, k: (k, j)),  # δ (contraction)
            pl.BlockSpec((bk, 1), lambda i, j, k: (k, 0)),  # ω
            pl.BlockSpec((bm, bs), lambda i, j, k: (i, j)),  # σ (update)
            pl.BlockSpec((bm, bs), lambda i, j, k: (i, j)),  # d (update)
            pl.BlockSpec((bm, bs), lambda i, j, k: (i, j)),  # δ (update)
        ],
        out_specs=pl.BlockSpec((bm, bs), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, s), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bs), jnp.float32)],
        interpret=interpret,
    )(lvl_arr, adjacency, sigma, depth, delta, omega_col, sigma, depth, delta)


# --------------------------------------------------------------------------
# Partial (pre-fold) variant for the 2-D distributed engine: rectangular
# adjacency block, gathered (σ, d, δ, ω) operands along the contraction
# dim, raw output t = A_block @ g with the g recompute fused in VMEM.
# The δ-update epilogue is deferred past the psum_scatter fold (see
# operators.DistributedPallasOperator and frontier_spmm.py).
#
# Chunked-operand (ring) mode: ``acc`` threads the running [m, s] partial
# through the ring steps of the pipelined expand — the VMEM accumulator
# is seeded from the carried tensor instead of zeros (see the frontier
# kernel for the schedule).
# --------------------------------------------------------------------------


def dependency_partial_kernel(
    lvl_ref,  # (1,1) i32
    a_ref,  # [bm, bk] adjacency-block tile
    sigma_k_ref,  # [bk, bs]
    depth_k_ref,  # [bk, bs]
    delta_k_ref,  # [bk, bs]
    omega_k_ref,  # [bk, 1]
    t_out_ref,  # [bm, bs]
    acc_ref,  # VMEM [bm, bs] f32
    *,
    k_steps: int,
):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    lvl = lvl_ref[0, 0]
    sigma_k = sigma_k_ref[...]
    safe_sigma = jnp.where(sigma_k > 0, sigma_k, 1.0)
    g = jnp.where(
        depth_k_ref[...] == lvl + 1,
        (1.0 + delta_k_ref[...] + omega_k_ref[...]) / safe_sigma,
        0.0,
    )
    acc_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.float32), g, preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _epilogue():
        t_out_ref[...] = acc_ref[...]


def dependency_partial_acc_kernel(
    lvl_ref,  # (1,1) i32
    a_ref,  # [bm, bk] adjacency-block tile
    sigma_k_ref,  # [bk, bs]
    depth_k_ref,  # [bk, bs]
    delta_k_ref,  # [bk, bs]
    omega_k_ref,  # [bk, 1]
    t_in_ref,  # [bm, bs] running ring accumulator
    t_out_ref,  # [bm, bs]
    acc_ref,  # VMEM [bm, bs] f32
    *,
    k_steps: int,
):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = t_in_ref[...]

    lvl = lvl_ref[0, 0]
    sigma_k = sigma_k_ref[...]
    safe_sigma = jnp.where(sigma_k > 0, sigma_k, 1.0)
    g = jnp.where(
        depth_k_ref[...] == lvl + 1,
        (1.0 + delta_k_ref[...] + omega_k_ref[...]) / safe_sigma,
        0.0,
    )
    acc_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.float32), g, preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _epilogue():
        t_out_ref[...] = acc_ref[...]


def dependency_partial_pallas(
    adjacency: jnp.ndarray,  # [m, kdim]
    sigma: jnp.ndarray,  # [kdim, s]
    depth: jnp.ndarray,  # [kdim, s]
    delta: jnp.ndarray,  # [kdim, s]
    omega: jnp.ndarray,  # [kdim]
    lvl: jnp.ndarray,
    *,
    acc: jnp.ndarray | None = None,  # [m, s] ring accumulator (chunked mode)
    bm: int = 128,
    bk: int = 128,
    bs: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Raw pallas_call; block-aligned shapes required (see ops.py)."""
    m, kdim = adjacency.shape
    _, s = sigma.shape
    assert m % bm == 0 and kdim % bk == 0 and s % bs == 0, (m, kdim, s, bm, bk, bs)
    k_steps = kdim // bk
    grid = (m // bm, s // bs, k_steps)

    lvl_arr = jnp.asarray(lvl, jnp.int32).reshape(1, 1)
    omega_col = omega.astype(jnp.float32).reshape(kdim, 1)
    in_specs = [
        pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),  # lvl
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),  # A block tile
        pl.BlockSpec((bk, bs), lambda i, j, k: (k, j)),  # σ (contraction)
        pl.BlockSpec((bk, bs), lambda i, j, k: (k, j)),  # d (contraction)
        pl.BlockSpec((bk, bs), lambda i, j, k: (k, j)),  # δ (contraction)
        pl.BlockSpec((bk, 1), lambda i, j, k: (k, 0)),  # ω
    ]
    args = [lvl_arr, adjacency, sigma, depth, delta, omega_col]
    if acc is None:
        kernel = functools.partial(dependency_partial_kernel, k_steps=k_steps)
    else:
        kernel = functools.partial(dependency_partial_acc_kernel, k_steps=k_steps)
        in_specs.append(pl.BlockSpec((bm, bs), lambda i, j, k: (i, j)))  # t_in
        args.append(acc)

    from jax.experimental.pallas import tpu as pltpu

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bs), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, s), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bs), jnp.float32)],
        interpret=interpret,
    )(*args)
