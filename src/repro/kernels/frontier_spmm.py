"""Pallas TPU kernel: fused forward BFS level (frontier SpMM).

The hot loop of MGBC's shortest-path counting is, per level,

    t      = A @ (σ ⊙ [d == lvl-1])
    newly  = (t > 0) ∧ (d < 0)
    d'     = lvl on newly;      σ' = σ + t on newly

A naive XLA lowering materializes the masked frontier ``F = σ⊙mask`` and
the product ``t`` in HBM (two extra n×s round-trips per level — the
dominant *memory-term* cost for small s).  This kernel fuses the mask
into the matmul operand load and the state update into the epilogue, so
per level the only HBM traffic is:  A once (tiled), σ/d once in, σ/d
once out.

Grid = (n/bm, s/bs, n/bk): classic k-innermost matmul tiling with an f32
VMEM accumulator.  The frontier operand tile is recomputed from the
(σ, d) tile on the fly — VMEM-resident, MXU-aligned (block sizes are
multiples of (8, 128) lanes; defaults 128/128/128, shrunk by ops.py for
small inputs).  The adjacency tile may be bf16 (0/1 values are exact) —
halving the A-stream bytes; the accumulator stays f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "frontier_spmm_kernel",
    "frontier_spmm_pallas",
    "frontier_partial_kernel",
    "frontier_partial_acc_kernel",
    "frontier_partial_pallas",
]


def frontier_spmm_kernel(
    lvl_ref,  # SMEM-ish (1,1) i32
    a_ref,  # [bm, bk] adjacency tile
    sigma_k_ref,  # [bk, bs] σ tile along contraction dim
    depth_k_ref,  # [bk, bs] d tile along contraction dim
    sigma_io_ref,  # [bm, bs] σ tile being updated
    depth_io_ref,  # [bm, bs] d tile being updated
    sigma_out_ref,  # [bm, bs]
    depth_out_ref,  # [bm, bs]
    acc_ref,  # VMEM scratch [bm, bs] f32
    *,
    k_steps: int,
):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    lvl = lvl_ref[0, 0]
    frontier = sigma_k_ref[...] * (depth_k_ref[...] == lvl - 1).astype(jnp.float32)
    acc_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.float32),
        frontier,
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == k_steps - 1)
    def _epilogue():
        t = acc_ref[...]
        depth = depth_io_ref[...]
        sigma = sigma_io_ref[...]
        newly = (t > 0) & (depth < 0)
        depth_out_ref[...] = jnp.where(newly, lvl, depth)
        sigma_out_ref[...] = sigma + jnp.where(newly, t, 0.0)


def frontier_spmm_pallas(
    adjacency: jnp.ndarray,
    sigma: jnp.ndarray,
    depth: jnp.ndarray,
    lvl: jnp.ndarray,
    *,
    bm: int = 128,
    bk: int = 128,
    bs: int = 128,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Raw pallas_call wrapper; shapes must already be block-aligned.

    Use :func:`repro.kernels.ops.frontier_spmm` for padding + dispatch.
    """
    n, _ = adjacency.shape
    _, s = sigma.shape
    assert n % bm == 0 and n % bk == 0 and s % bs == 0, (n, s, bm, bk, bs)
    k_steps = n // bk
    grid = (n // bm, s // bs, k_steps)

    lvl_arr = jnp.asarray(lvl, jnp.int32).reshape(1, 1)
    kernel = functools.partial(frontier_spmm_kernel, k_steps=k_steps)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),  # lvl
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),  # A tile
            pl.BlockSpec((bk, bs), lambda i, j, k: (k, j)),  # σ (contraction)
            pl.BlockSpec((bk, bs), lambda i, j, k: (k, j)),  # d (contraction)
            pl.BlockSpec((bm, bs), lambda i, j, k: (i, j)),  # σ (updated)
            pl.BlockSpec((bm, bs), lambda i, j, k: (i, j)),  # d (updated)
        ],
        out_specs=[
            pl.BlockSpec((bm, bs), lambda i, j, k: (i, j)),
            pl.BlockSpec((bm, bs), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, s), jnp.float32),
            jax.ShapeDtypeStruct((n, s), jnp.int32),
        ],
        scratch_shapes=[_vmem_scratch(bm, bs)],
        interpret=interpret,
    )(lvl_arr, adjacency, sigma, depth, sigma, depth)


def _vmem_scratch(bm: int, bs: int):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM((bm, bs), jnp.float32)


# --------------------------------------------------------------------------
# Partial (pre-fold) variant for the 2-D distributed engine: the adjacency
# is one device's rectangular block A[rows_i, cols_j], the (σ, d) operands
# are the row-gathered column slice, and the output is the *raw* masked
# product t = A_block @ (σ ⊙ [d = lvl-1]).  The state-update epilogue is
# deferred: it needs the psum_scatter-folded t, so it runs in jnp on the
# owned chunk (see operators.DistributedPallasOperator).  The operand
# fusion — recomputing the frontier tile from (σ, d) in VMEM instead of
# materializing it in HBM — is identical to the square kernel above.
#
# Chunked-operand (ring) mode: the pipelined expand schedule feeds the
# kernel one row-chunk of operands per ring step and threads a running
# [m, s] accumulator through the steps (``acc``).  Seeding the VMEM
# accumulator from the carried tensor keeps the per-step combine inside
# the kernel — no separate [m, s] add round-trips HBM between steps.
# --------------------------------------------------------------------------


def frontier_partial_kernel(
    lvl_ref,  # (1,1) i32
    a_ref,  # [bm, bk] adjacency-block tile
    sigma_k_ref,  # [bk, bs] gathered σ tile (contraction dim)
    depth_k_ref,  # [bk, bs] gathered d tile (contraction dim)
    t_out_ref,  # [bm, bs] partial product
    acc_ref,  # VMEM scratch [bm, bs] f32
    *,
    k_steps: int,
):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    lvl = lvl_ref[0, 0]
    frontier = sigma_k_ref[...] * (depth_k_ref[...] == lvl - 1).astype(jnp.float32)
    acc_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.float32),
        frontier,
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == k_steps - 1)
    def _epilogue():
        t_out_ref[...] = acc_ref[...]


def frontier_partial_acc_kernel(
    lvl_ref,  # (1,1) i32
    a_ref,  # [bm, bk] adjacency-block tile
    sigma_k_ref,  # [bk, bs] chunk σ tile (contraction dim)
    depth_k_ref,  # [bk, bs] chunk d tile (contraction dim)
    t_in_ref,  # [bm, bs] running ring accumulator
    t_out_ref,  # [bm, bs] accumulator + this chunk's product
    acc_ref,  # VMEM scratch [bm, bs] f32
    *,
    k_steps: int,
):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = t_in_ref[...]

    lvl = lvl_ref[0, 0]
    frontier = sigma_k_ref[...] * (depth_k_ref[...] == lvl - 1).astype(jnp.float32)
    acc_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.float32),
        frontier,
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == k_steps - 1)
    def _epilogue():
        t_out_ref[...] = acc_ref[...]


def frontier_partial_pallas(
    adjacency: jnp.ndarray,  # [m, kdim] rectangular block
    sigma: jnp.ndarray,  # [kdim, s]
    depth: jnp.ndarray,  # [kdim, s]
    lvl: jnp.ndarray,
    *,
    acc: jnp.ndarray | None = None,  # [m, s] ring accumulator (chunked mode)
    bm: int = 128,
    bk: int = 128,
    bs: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Raw pallas_call; block-aligned shapes required (see ops.py)."""
    m, kdim = adjacency.shape
    _, s = sigma.shape
    assert m % bm == 0 and kdim % bk == 0 and s % bs == 0, (m, kdim, s, bm, bk, bs)
    k_steps = kdim // bk
    grid = (m // bm, s // bs, k_steps)

    lvl_arr = jnp.asarray(lvl, jnp.int32).reshape(1, 1)
    in_specs = [
        pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),  # lvl
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),  # A block tile
        pl.BlockSpec((bk, bs), lambda i, j, k: (k, j)),  # σ (contraction)
        pl.BlockSpec((bk, bs), lambda i, j, k: (k, j)),  # d (contraction)
    ]
    args = [lvl_arr, adjacency, sigma, depth]
    if acc is None:
        kernel = functools.partial(frontier_partial_kernel, k_steps=k_steps)
    else:
        kernel = functools.partial(frontier_partial_acc_kernel, k_steps=k_steps)
        in_specs.append(pl.BlockSpec((bm, bs), lambda i, j, k: (i, j)))  # t_in
        args.append(acc)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bs), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, s), jnp.float32),
        scratch_shapes=[_vmem_scratch(bm, bs)],
        interpret=interpret,
    )(*args)
