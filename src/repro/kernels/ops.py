"""jit'd public wrappers for the Pallas kernels.

Each op:
  * pads inputs to block multiples (MXU lanes: multiples of (8, 128)),
  * dispatches to the Pallas kernel (interpret mode on CPU — the
    container validates kernel semantics; TPU executes them compiled),
  * falls back to the pure-jnp reference when ``use_pallas=False``
    (XLA path; useful for A/B perf comparison and as the grad path).

Block sizes adapt downward for small inputs so tests can sweep tiny
shapes; production shapes use the 128-aligned defaults.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.blocked_spmm import (
    dependency_sparse_pallas,
    frontier_sparse_pallas,
    tiles_to_dense,
)
from repro.kernels.dependency_spmm import (
    dependency_partial_pallas,
    dependency_spmm_pallas,
)
from repro.kernels.frontier_spmm import frontier_partial_pallas, frontier_spmm_pallas
from repro.kernels.segment_bag import segment_bag_pallas

__all__ = [
    "frontier_spmm",
    "dependency_spmm",
    "frontier_spmm_partial",
    "dependency_spmm_partial",
    "frontier_spmm_sparse",
    "dependency_spmm_sparse",
    "segment_bag",
    "checksum_append",
    "checksum_residual",
    "bucket_index",
    "on_tpu",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jnp.ndarray, axis: int, multiple: int, fill=0):
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


def _pick_block(dim: int, preferred: int, lane: int) -> int:
    """Largest lane-aligned block ≤ preferred covering dim efficiently."""
    if dim >= preferred:
        return preferred
    return max(lane, ((dim + lane - 1) // lane) * lane)


def _square_geometry(n: int, s: int, bm: int, bk: int, bs: int):
    """Block sizes + padded n for the square (fused-epilogue) kernels:
    n must be a multiple of lcm(bm, bk) so the update and contraction
    tilings agree."""
    bm, bk, bs = _pick_block(n, bm, 8), _pick_block(n, bk, 8), _pick_block(s, bs, 128)
    npad = n + (-n) % (bm * bk // math.gcd(bm, bk))
    return bm, bk, bs, npad


def _rect_geometry(m: int, kdim: int, s: int, bm: int, bk: int, bs: int):
    """Block sizes for the rectangular partial kernels (the shared
    _pick_block plumbing of the frontier/dependency partial wrappers)."""
    return _pick_block(m, bm, 8), _pick_block(kdim, bk, 8), _pick_block(s, bs, 128)


def _pad_cols(bs: int, *pairs):
    """Pad each (array, fill) pair along axis 1 to a multiple of ``bs``
    (the shared operand plumbing of the two blocked-sparse wrappers);
    ``None`` arrays pass through (the optional ring ``acc``)."""
    return tuple(
        None if a is None else _pad_to(a, 1, bs, fill=f) for a, f in pairs
    )


def bucket_index(dist: jnp.ndarray, delta: float, unreached: int = -1) -> jnp.ndarray:
    """i32 bucket ids ``⌊d/Δ⌋`` of a tentative-distance array.

    Unreached vertices carry ``+inf`` distance; casting ``inf/Δ`` to int
    is undefined, so the floor is computed on a 0-substituted copy and
    masked back to ``unreached`` (the bucketed traversal's analogue of
    the level array's -1).  Shared by the weighted round's 2-degree
    depth derivation and its max-bucket reduction (core/driver.py).
    """
    delta_w = jnp.float32(delta)
    finite = jnp.isfinite(dist)
    safe = jnp.where(finite, dist, 0.0)
    return jnp.where(
        finite, jnp.floor(safe / delta_w).astype(jnp.int32), jnp.int32(unreached)
    )


def checksum_append(x: jnp.ndarray) -> jnp.ndarray:
    """Append the ABFT ones-checksum lane to a batched [n, s] operand.

    The extra column is the row-wise sum of the real lanes, so after any
    linear map ``t = A @ x`` (including the distributed expand / ring /
    fold pipeline — all_gather, per-block partials and psum_scatter are
    linear per column) the output's last column must equal the sum of
    its real columns.  The lane rides the existing s-axis padding
    machinery of the SpMM wrappers; :func:`checksum_residual` verifies
    the invariant on the product.
    """
    return jnp.concatenate([x, x.sum(axis=1, keepdims=True)], axis=1)


def checksum_residual(t: jnp.ndarray) -> jnp.ndarray:
    """Relative ABFT residual of a checksum-extended SpMM product.

    ``t`` is [n, s+1] with the ones-checksum lane last.  Returns the f32
    scalar ``max_i |t[i, -1] - Σ_j t[i, j]| / (1 + Σ_j |t[i, j]|)`` —
    ~1e-6 for a healthy f32 reduction, orders of magnitude larger when a
    flipped bit or a bad partial fold broke the column-sum invariant.
    """
    real = t[:, :-1]
    resid = jnp.abs(t[:, -1] - real.sum(axis=1))
    scale = 1.0 + jnp.abs(real).sum(axis=1)
    return jnp.max(resid / scale).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret", "bm", "bk", "bs"))
def frontier_spmm(
    adjacency,
    sigma,
    depth,
    lvl,
    *,
    use_pallas: bool = True,
    interpret: bool | None = None,
    bm: int = 128,
    bk: int = 128,
    bs: int = 128,
):
    """Fused forward BFS level. See kernels/frontier_spmm.py."""
    if not use_pallas:
        return ref.frontier_spmm_ref(adjacency, sigma, depth, lvl)
    if interpret is None:
        interpret = not on_tpu()
    n, s = sigma.shape
    bm, bk, bs, npad = _square_geometry(n, s, bm, bk, bs)
    a = jnp.pad(adjacency, ((0, npad - n), (0, npad - n))) if npad != n else adjacency
    sg = _pad_to(_pad_to(sigma, 0, npad), 1, bs)
    dp = _pad_to(_pad_to(depth, 0, npad, fill=-1), 1, bs, fill=-1)
    sigma_out, depth_out = frontier_spmm_pallas(
        a, sg, dp, lvl, bm=bm, bk=bk, bs=bs, interpret=interpret
    )
    return sigma_out[:n, :s], depth_out[:n, :s]


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret", "bm", "bk", "bs"))
def dependency_spmm(
    adjacency,
    sigma,
    depth,
    delta,
    omega,
    lvl,
    *,
    use_pallas: bool = True,
    interpret: bool | None = None,
    bm: int = 128,
    bk: int = 128,
    bs: int = 128,
):
    """Fused backward dependency level. See kernels/dependency_spmm.py."""
    if not use_pallas:
        return ref.dependency_spmm_ref(adjacency, sigma, depth, delta, omega, lvl)
    if interpret is None:
        interpret = not on_tpu()
    n, s = sigma.shape
    bm, bk, bs, npad = _square_geometry(n, s, bm, bk, bs)
    a = jnp.pad(adjacency, ((0, npad - n), (0, npad - n))) if npad != n else adjacency
    sg = _pad_to(_pad_to(sigma, 0, npad), 1, bs)
    dp = _pad_to(_pad_to(depth, 0, npad, fill=-1), 1, bs, fill=-1)
    dl = _pad_to(_pad_to(delta, 0, npad), 1, bs)
    om = _pad_to(omega, 0, npad)
    out = dependency_spmm_pallas(
        a, sg, dp, dl, om, lvl, bm=bm, bk=bk, bs=bs, interpret=interpret
    )
    return out[:n, :s]


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret", "bm", "bk", "bs"))
def frontier_spmm_partial(
    adjacency,
    sigma,
    depth,
    lvl,
    *,
    acc=None,
    use_pallas: bool = True,
    interpret: bool | None = None,
    bm: int = 128,
    bk: int = 128,
    bs: int = 128,
):
    """Pre-fold forward partial on a rectangular adjacency block.

    ``adjacency`` is [m, k] (one device's A[rows_i, cols_j]); ``sigma``
    and ``depth`` are the row-gathered [k, s] operands.  Returns the raw
    t = A_block @ (σ ⊙ [d = lvl-1]) f32 [m, s] — callers fold the C
    partials with psum_scatter and apply the state update afterwards.

    Chunked-operand (ring) mode: with ``acc`` (f32 [m, s]) the operands
    are one row-chunk of the gathered slice and the result is
    ``acc + A_chunk @ frontier_chunk`` — the running combine of the
    pipelined expand schedule, fused into the kernel's accumulator init.
    See kernels/frontier_spmm.py (partial variants).
    """
    if not use_pallas:
        t = ref.frontier_partial_ref(adjacency, sigma, depth, lvl)
        return t if acc is None else acc + t
    if interpret is None:
        interpret = not on_tpu()
    m, kdim = adjacency.shape
    _, s = sigma.shape
    bm, bk, bs = _rect_geometry(m, kdim, s, bm, bk, bs)
    a = _pad_to(_pad_to(adjacency, 0, bm), 1, bk)
    sg = _pad_to(_pad_to(sigma, 0, bk), 1, bs)
    dp = _pad_to(_pad_to(depth, 0, bk, fill=-1), 1, bs, fill=-1)
    ac = None if acc is None else _pad_to(_pad_to(acc, 0, bm), 1, bs)
    t = frontier_partial_pallas(
        a, sg, dp, lvl, acc=ac, bm=bm, bk=bk, bs=bs, interpret=interpret
    )
    return t[:m, :s]


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret", "bm", "bk", "bs"))
def dependency_spmm_partial(
    adjacency,
    sigma,
    depth,
    delta,
    omega,
    lvl,
    *,
    acc=None,
    use_pallas: bool = True,
    interpret: bool | None = None,
    bm: int = 128,
    bk: int = 128,
    bs: int = 128,
):
    """Pre-fold backward partial on a rectangular adjacency block.

    Operands are the row-gathered [k, s] (σ, d, δ) and [k] ω; the g
    recompute is fused into the block matmul.  Returns t = A_block @ g
    f32 [m, s].  With ``acc`` (f32 [m, s]) the operands are one row-chunk
    and the result is ``acc + A_chunk @ g_chunk`` — the pipelined-expand
    running combine.  See kernels/dependency_spmm.py (partial variants).
    """
    if not use_pallas:
        t = ref.dependency_partial_ref(adjacency, sigma, depth, delta, omega, lvl)
        return t if acc is None else acc + t
    if interpret is None:
        interpret = not on_tpu()
    m, kdim = adjacency.shape
    _, s = sigma.shape
    bm, bk, bs = _rect_geometry(m, kdim, s, bm, bk, bs)
    a = _pad_to(_pad_to(adjacency, 0, bm), 1, bk)
    sg = _pad_to(_pad_to(sigma, 0, bk), 1, bs)
    dp = _pad_to(_pad_to(depth, 0, bk, fill=-1), 1, bs, fill=-1)
    dl = _pad_to(_pad_to(delta, 0, bk), 1, bs)
    om = _pad_to(omega, 0, bk)
    ac = None if acc is None else _pad_to(_pad_to(acc, 0, bm), 1, bs)
    t = dependency_partial_pallas(
        a, sg, dp, dl, om, lvl, acc=ac, bm=bm, bk=bk, bs=bs, interpret=interpret
    )
    return t[:m, :s]


@functools.partial(jax.jit, static_argnames=("m", "use_pallas", "interpret", "bs"))
def frontier_spmm_sparse(
    tiles,
    tile_rows,
    tile_cols,
    sigma,
    depth,
    lvl,
    *,
    m: int,
    acc=None,
    use_pallas: bool = True,
    interpret: bool | None = None,
    bs: int = 128,
):
    """Blocked-sparse pre-fold forward partial (BCSR tile list).

    ``tiles`` [T, bm, bk] / ``tile_rows`` / ``tile_cols`` are one
    device's stored nonzero tiles (row-sorted, row-complete — build with
    :meth:`repro.graphs.partition.TwoDPartition.blocked_sparse`);
    ``sigma``/``depth`` are the gathered [kdim, s] operands.  Returns the
    raw t = A_block @ (σ ⊙ [d = lvl-1]) f32 [m, s], touching only the
    stored tiles — A-stream bytes O(T · bm · bk) instead of O(m · kdim).

    Modes mirror :func:`frontier_spmm_partial`: full (barrier schedule,
    operands = the whole gathered slice), per-ring-chunk partial
    (operands = one [chunk, s] chunk, tiles = that ring slot's slice),
    and chunked-``acc`` (the running ring combine seeds the kernel's
    VMEM accumulator).  ``m`` is static: the fold-partial row count
    (C·chunk), not derivable from the tile list.
    """
    if not use_pallas:
        a = tiles_to_dense(tiles, tile_rows, tile_cols, m, sigma.shape[0])
        t = ref.frontier_partial_ref(a, sigma, depth, lvl)
        return t if acc is None else acc + t
    if interpret is None:
        interpret = not on_tpu()
    s = sigma.shape[1]
    bs = _pick_block(s, bs, 128)
    sg, dp, ac = _pad_cols(bs, (sigma, 0), (depth, -1), (acc, 0))
    t = frontier_sparse_pallas(
        tiles, tile_rows, tile_cols, sg, dp, lvl, m=m, acc=ac, bs=bs,
        interpret=interpret,
    )
    return t[:, :s]


@functools.partial(jax.jit, static_argnames=("m", "use_pallas", "interpret", "bs"))
def dependency_spmm_sparse(
    tiles,
    tile_rows,
    tile_cols,
    sigma,
    depth,
    delta,
    omega,
    lvl,
    *,
    m: int,
    acc=None,
    use_pallas: bool = True,
    interpret: bool | None = None,
    bs: int = 128,
):
    """Blocked-sparse pre-fold backward partial (BCSR tile list).

    Operands are the gathered [kdim, s] (σ, d, δ) and [kdim] ω; the g
    recompute is fused per stored tile.  Returns t = A_block @ g f32
    [m, s].  Same full / ring-chunk / chunked-``acc`` modes as
    :func:`frontier_spmm_sparse`.
    """
    if not use_pallas:
        a = tiles_to_dense(tiles, tile_rows, tile_cols, m, sigma.shape[0])
        t = ref.dependency_partial_ref(a, sigma, depth, delta, omega, lvl)
        return t if acc is None else acc + t
    if interpret is None:
        interpret = not on_tpu()
    s = sigma.shape[1]
    bs = _pick_block(s, bs, 128)
    sg, dp, dl, ac = _pad_cols(bs, (sigma, 0), (depth, -1), (delta, 0), (acc, 0))
    t = dependency_sparse_pallas(
        tiles, tile_rows, tile_cols, sg, dp, dl, omega, lvl, m=m, acc=ac, bs=bs,
        interpret=interpret,
    )
    return t[:, :s]


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret", "bd"))
def segment_bag(
    table,
    indices,
    weights=None,
    *,
    use_pallas: bool = True,
    interpret: bool | None = None,
    bd: int = 128,
):
    """EmbeddingBag(sum). See kernels/segment_bag.py."""
    if not use_pallas:
        return ref.segment_bag_ref(table, indices, weights)
    if interpret is None:
        interpret = not on_tpu()
    V, D = table.shape
    bd = _pick_block(D, bd, 128)
    t = _pad_to(table, 1, bd)
    out = segment_bag_pallas(t, indices, weights, bd=bd, interpret=interpret)
    return out[:, :D]
