"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics; the kernels must match them exactly (f32) for
every shape/dtype combination the tests sweep.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "frontier_spmm_ref",
    "dependency_spmm_ref",
    "frontier_partial_ref",
    "dependency_partial_ref",
    "segment_bag_ref",
]


def frontier_spmm_ref(adjacency, sigma, depth, lvl):
    """One fused forward BFS level (cf. core/engine._forward_level).

    Args:
      adjacency: [n, n] 0/1 (any float dtype).
      sigma:     f32 [n, s] path counts.
      depth:     i32 [n, s] discovery levels (-1 unreached).
      lvl:       i32 scalar — the level being expanded.

    Returns (sigma_out, depth_out).
    """
    frontier = sigma * (depth == lvl - 1)
    contrib = adjacency.astype(jnp.float32) @ frontier
    newly = (contrib > 0) & (depth < 0)
    depth_out = jnp.where(newly, lvl, depth)
    sigma_out = sigma + jnp.where(newly, contrib, 0.0)
    return sigma_out, depth_out


def dependency_spmm_ref(adjacency, sigma, depth, delta, omega, lvl):
    """One fused backward dependency level (cf. engine._backward_level).

    Args:
      adjacency: [n, n] 0/1.
      sigma:     f32 [n, s].
      depth:     i32 [n, s].
      delta:     f32 [n, s] running dependencies.
      omega:     f32 [n] 1-degree weights.
      lvl:       i32 scalar.

    Returns delta_out f32 [n, s].
    """
    safe_sigma = jnp.where(sigma > 0, sigma, 1.0)
    g = jnp.where(
        depth == lvl + 1, (1.0 + delta + omega[:, None]) / safe_sigma, 0.0
    )
    t = adjacency.astype(jnp.float32) @ g
    return delta + jnp.where(depth == lvl, sigma * t, 0.0)


def frontier_partial_ref(adjacency, sigma, depth, lvl):
    """Pre-fold forward partial for a rectangular adjacency block.

    Args:
      adjacency: [m, k] 0/1 block (any float dtype).
      sigma:     f32 [k, s] gathered path counts (contraction side).
      depth:     i32 [k, s] gathered discovery levels.
      lvl:       i32 scalar.

    Returns t f32 [m, s] = A_block @ (σ ⊙ [d = lvl-1]); the state update
    happens after the cross-device fold (operators.DistributedPallasOperator).
    """
    frontier = sigma * (depth == lvl - 1)
    return adjacency.astype(jnp.float32) @ frontier


def dependency_partial_ref(adjacency, sigma, depth, delta, omega, lvl):
    """Pre-fold backward partial for a rectangular adjacency block.

    Args:
      adjacency: [m, k] 0/1 block.
      sigma:     f32 [k, s] (contraction side).
      depth:     i32 [k, s].
      delta:     f32 [k, s].
      omega:     f32 [k].
      lvl:       i32 scalar.

    Returns t f32 [m, s] = A_block @ g with g = (1+δ+ω)/σ on d = lvl+1.
    """
    safe_sigma = jnp.where(sigma > 0, sigma, 1.0)
    g = jnp.where(
        depth == lvl + 1, (1.0 + delta + omega[:, None]) / safe_sigma, 0.0
    )
    return adjacency.astype(jnp.float32) @ g


def segment_bag_ref(table, indices, weights=None):
    """EmbeddingBag (sum mode) — the recsys/GNN gather-reduce primitive.

    Args:
      table:   [V, D] embedding rows.
      indices: i32 [B, L] row ids per bag; -1 = padding.
      weights: optional f32 [B, L] per-sample weights.

    Returns f32 [B, D]: out[b] = Σ_l w[b,l] * table[indices[b,l]].
    """
    mask = (indices >= 0).astype(jnp.float32)
    if weights is not None:
        mask = mask * weights
    safe = jnp.maximum(indices, 0)
    gathered = table.astype(jnp.float32)[safe]  # [B, L, D]
    return (gathered * mask[..., None]).sum(axis=1)
