"""Pallas TPU kernels for MGBC's compute hot spots + the EmbeddingBag.

Each kernel ships three layers:
  <name>.py — ``pl.pallas_call`` + explicit BlockSpec VMEM tiling
  ops.py    — jit'd wrapper (padding, dispatch, CPU interpret fallback)
  ref.py    — pure-jnp oracle (the semantics; tests assert allclose)
"""
from repro.kernels.ops import (
    dependency_spmm,
    dependency_spmm_sparse,
    frontier_spmm,
    frontier_spmm_sparse,
    segment_bag,
)

__all__ = [
    "frontier_spmm",
    "dependency_spmm",
    "frontier_spmm_sparse",
    "dependency_spmm_sparse",
    "segment_bag",
]
