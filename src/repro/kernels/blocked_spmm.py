"""Pallas TPU kernels: blocked-sparse (BCSR-style) traversal SpMMs.

The 2-D distributed engine's dense-block kernels stream the whole
[C·chunk, R·chunk] adjacency block from HBM every level — O(n_pad²/p)
bytes per device, regardless of sparsity.  RMAT/real-world graphs are
extremely sparse, so the block is mostly zero tiles; these kernels take
the tiled block-compressed layout of
:meth:`repro.graphs.partition.TwoDPartition.blocked_sparse` — only the
nonzero (bm × bk) tiles, stacked as [T, bm, bk] with per-tile row/col
index maps — and iterate *only the stored tiles*, dropping the A-stream
to O(nnz_tiles · bm · bk) bytes per level.

Grid = (s/bs, T) with the tile index minor.  The tile row/col ids are
**scalar-prefetched** (``pltpu.PrefetchScalarGridSpec``): the BlockSpec
index maps read them to DMA the right operand tile ([tile_cols[t]·bk
rows of the gathered operands]) and output tile ([tile_rows[t]·bm rows
of the partial product]) ahead of the kernel body.  Tiles arrive sorted
by output tile-row, so each tile-row is one consecutive run of grid
steps: the f32 VMEM accumulator initializes at the run's first tile
(from zeros, or from the carried ring accumulator in ``acc`` mode) and
flushes to the output block at the run's last tile.  The layout
guarantees every tile-row holds at least one (possibly all-zero filler)
tile, so every output block is written exactly once per (row, s-block).

All four kernel variants — frontier/dependency × zero-init/carried-acc
— are products of one :func:`make_sparse_kernel` factory: the tile-row
run accumulate is written once, parameterized by the fused operand math
and the accumulator init.

Both kernels are *partial* (pre-fold) forms mirroring the dense
``frontier_partial_pallas`` / ``dependency_partial_pallas``: the operand
fusion (frontier mask / g recompute in VMEM) is identical, the state
update stays deferred past the psum_scatter fold.  The same entry point
serves the full-block barrier schedule (operands = the row-gathered
[R·chunk, s] slice, tiles = the whole block's list) and the
ring-pipelined schedule (operands = one [chunk, s] chunk, tiles = that
ring slot's slice, ``acc`` = the running partial carried between hops).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "make_sparse_kernel",
    "frontier_sparse_kernel",
    "frontier_sparse_acc_kernel",
    "frontier_sparse_pallas",
    "dependency_sparse_kernel",
    "dependency_sparse_acc_kernel",
    "dependency_sparse_pallas",
    "tiles_to_dense",
]


def tiles_to_dense(tiles, tile_rows, tile_cols, m: int, kdim: int) -> jnp.ndarray:
    """Reconstruct the dense [m, kdim] block from a tile list (jnp).

    Reference/debug path only — the kernels never materialize this.
    Filler/padding tiles are all-zero, so scatter-add is exact.
    """
    t, bm, bk = tiles.shape
    grid = jnp.zeros((m // bm, kdim // bk, bm, bk), jnp.float32)
    grid = grid.at[tile_rows, tile_cols].add(tiles.astype(jnp.float32))
    return grid.transpose(0, 2, 1, 3).reshape(m, kdim)


def _row_run_bounds(rows_ref, t, num_tiles: int):
    """(first, last) flags of tile t within its output tile-row run."""
    row = rows_ref[t]
    first = (t == 0) | (rows_ref[jnp.maximum(t - 1, 0)] != row)
    last = (t == num_tiles - 1) | (rows_ref[jnp.minimum(t + 1, num_tiles - 1)] != row)
    return first, last


def _frontier_operand(lvl, sigma_k_ref, depth_k_ref):
    """Fused forward operand: the masked frontier σ ⊙ [d = lvl-1]."""
    return sigma_k_ref[...] * (depth_k_ref[...] == lvl - 1).astype(jnp.float32)


def _dependency_operand(lvl, sigma_k_ref, depth_k_ref, delta_k_ref, omega_k_ref):
    """Fused backward operand: g = (1 + δ + ω) / σ on d = lvl+1."""
    sigma_k = sigma_k_ref[...]
    safe_sigma = jnp.where(sigma_k > 0, sigma_k, 1.0)
    return jnp.where(
        depth_k_ref[...] == lvl + 1,
        (1.0 + delta_k_ref[...] + omega_k_ref[...]) / safe_sigma,
        0.0,
    )


def make_sparse_kernel(operand_fn, *, carried: bool):
    """Kernel factory: ONE copy of the tile-row-run accumulate.

    All four sparse traversal kernels are the same program — initialize
    the VMEM accumulator at a tile-row run's first tile, fold one
    ``A_tile @ operand_tile`` product per grid step, flush at the run's
    last tile — differing only in the fused operand math (``operand_fn``
    builds the [bk, bs] RHS tile from the prefetched level and the
    operand refs) and the accumulator init (``carried=True`` seeds from
    the ring schedule's ``t_in`` partial instead of zeros).  The factory
    keeps that program in one place; the module-level kernel names below
    are its four products.

    Emitted signature (positional refs, matching ``_sparse_call``):
        rows_ref, cols_ref, lvl_ref   SMEM i32 (scalar prefetch)
        a_ref                         [1, bm, bk] stored tile
        *operand_refs                 [bk, bs]-tiled operands at tile_cols[t]
        [t_in_ref]                    [bm, bs] ring accumulator (carried)
        t_out_ref                     [bm, bs] partial at tile_rows[t]
        acc_ref                       VMEM scratch [bm, bs] f32
    """

    def kernel(rows_ref, cols_ref, lvl_ref, a_ref, *refs, num_tiles: int):
        acc_ref, t_out_ref = refs[-1], refs[-2]
        t_in_ref = refs[-3] if carried else None
        operand_refs = refs[: -3 if carried else -2]
        t = pl.program_id(1)
        first, last = _row_run_bounds(rows_ref, t, num_tiles)

        @pl.when(first)
        def _init():
            acc_ref[...] = (
                jnp.zeros_like(acc_ref) if t_in_ref is None else t_in_ref[...]
            )

        rhs = operand_fn(lvl_ref[0], *operand_refs)
        acc_ref[...] += jnp.dot(
            a_ref[0].astype(jnp.float32), rhs, preferred_element_type=jnp.float32
        )

        @pl.when(last)
        def _flush():
            t_out_ref[...] = acc_ref[...]

    return kernel


frontier_sparse_kernel = make_sparse_kernel(_frontier_operand, carried=False)
frontier_sparse_acc_kernel = make_sparse_kernel(_frontier_operand, carried=True)
dependency_sparse_kernel = make_sparse_kernel(_dependency_operand, carried=False)
dependency_sparse_acc_kernel = make_sparse_kernel(_dependency_operand, carried=True)


def _sparse_call(kernel_pair, m, s, bm, bk, bs, num_tiles, operand_specs, args, acc, interpret):
    """Shared pallas_call shell of the two sparse SpMMs.

    ``kernel_pair`` = (zero-init, carried-acc) factory products — the
    module-level names above, so the public kernels ARE what runs.
    ``args`` = (rows, cols, lvl, tiles, *operands); operand tiles index
    via cols_ref, the output (and ``acc`` input) via rows_ref.
    """
    out_spec = pl.BlockSpec((bm, bs), lambda j, t, rows, cols, lvl: (rows[t], j))
    in_specs = [
        pl.BlockSpec((1, bm, bk), lambda j, t, rows, cols, lvl: (t, 0, 0)),  # tile
        *operand_specs,
    ]
    kernel = functools.partial(kernel_pair[acc is not None], num_tiles=num_tiles)
    if acc is not None:
        in_specs.append(out_spec)  # t_in rides the output block index
        args = args + (acc,)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,  # rows, cols, lvl
        grid=(s // bs, num_tiles),
        in_specs=in_specs,
        out_specs=out_spec,
        scratch_shapes=[pltpu.VMEM((bm, bs), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, s), jnp.float32),
        interpret=interpret,
    )(*args)


def frontier_sparse_pallas(
    tiles: jnp.ndarray,  # [T, bm, bk] stored tiles (row-sorted, row-complete)
    tile_rows: jnp.ndarray,  # i32 [T]
    tile_cols: jnp.ndarray,  # i32 [T]
    sigma: jnp.ndarray,  # [kdim, s] gathered (or ring-chunk) operand
    depth: jnp.ndarray,  # [kdim, s]
    lvl: jnp.ndarray,
    *,
    m: int,  # output rows (C·chunk)
    acc: jnp.ndarray | None = None,  # [m, s] ring accumulator (chunked mode)
    bs: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Raw pallas_call; shapes must be tile-aligned (see ops.py)."""
    num_tiles, bm, bk = tiles.shape
    kdim, s = sigma.shape
    assert m % bm == 0 and kdim % bk == 0 and s % bs == 0, (m, kdim, s, bm, bk, bs)
    lvl_arr = jnp.asarray(lvl, jnp.int32).reshape(1)
    operand_specs = [
        pl.BlockSpec((bk, bs), lambda j, t, rows, cols, lvl: (cols[t], j)),  # σ
        pl.BlockSpec((bk, bs), lambda j, t, rows, cols, lvl: (cols[t], j)),  # d
    ]
    args = (tile_rows, tile_cols, lvl_arr, tiles, sigma, depth)
    return _sparse_call(
        (frontier_sparse_kernel, frontier_sparse_acc_kernel),
        m, s, bm, bk, bs, num_tiles, operand_specs, args, acc, interpret,
    )


def dependency_sparse_pallas(
    tiles: jnp.ndarray,  # [T, bm, bk]
    tile_rows: jnp.ndarray,  # i32 [T]
    tile_cols: jnp.ndarray,  # i32 [T]
    sigma: jnp.ndarray,  # [kdim, s]
    depth: jnp.ndarray,  # [kdim, s]
    delta: jnp.ndarray,  # [kdim, s]
    omega: jnp.ndarray,  # [kdim]
    lvl: jnp.ndarray,
    *,
    m: int,
    acc: jnp.ndarray | None = None,
    bs: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Raw pallas_call; shapes must be tile-aligned (see ops.py)."""
    num_tiles, bm, bk = tiles.shape
    kdim, s = sigma.shape
    assert m % bm == 0 and kdim % bk == 0 and s % bs == 0, (m, kdim, s, bm, bk, bs)
    lvl_arr = jnp.asarray(lvl, jnp.int32).reshape(1)
    omega_col = omega.astype(jnp.float32).reshape(kdim, 1)
    operand_specs = [
        pl.BlockSpec((bk, bs), lambda j, t, rows, cols, lvl: (cols[t], j)),  # σ
        pl.BlockSpec((bk, bs), lambda j, t, rows, cols, lvl: (cols[t], j)),  # d
        pl.BlockSpec((bk, bs), lambda j, t, rows, cols, lvl: (cols[t], j)),  # δ
        pl.BlockSpec((bk, 1), lambda j, t, rows, cols, lvl: (cols[t], 0)),  # ω
    ]
    args = (tile_rows, tile_cols, lvl_arr, tiles, sigma, depth, delta, omega_col)
    return _sparse_call(
        (dependency_sparse_kernel, dependency_sparse_acc_kernel),
        m, s, bm, bk, bs, num_tiles, operand_specs, args, acc, interpret,
    )
