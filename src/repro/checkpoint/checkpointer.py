"""Durable state: sharded PyTree checkpoints and the BC round snapshot.

Two checkpoint families live here:

* :class:`Checkpointer` / :class:`CheckpointManager` — PyTrees of arrays
  (LM/GNN training state), one directory per step:

      <root>/step_000100/
          manifest.json      — tree structure, shapes/dtypes, content
                               hashes, user metadata (data cursor, rng,
                               mesh shape)
          shard_p0.npz       — this process's addressable leaf arrays

  On a real multi-host cluster every process writes its own
  ``shard_p{i}`` with its addressable shards; in this single-process
  container p0 holds everything.  Restore validates hashes and tree
  structure, so a torn or partial checkpoint is detected (commit marker
  written last), which is the restart-safety property the
  fault-tolerance layer relies on: a failed write never becomes the
  resume point.

* :class:`BCCheckpoint` — the BC driver's (partial BC, n_s bookkeeping,
  committed rounds) triple, one atomic npz per run, with the committed
  set namespaced per replica ledger for the multi-ledger straggler
  scheduler (``BCDriver(straggler=...)``, core/driver.py).
"""
from __future__ import annotations

import hashlib
import json
import logging
import os
import queue
import re
import shutil
import threading
from typing import Any

import numpy as np

import jax

__all__ = ["Checkpointer", "CheckpointManager", "BCCheckpoint", "DEFAULT_GENERATIONS"]

log = logging.getLogger(__name__)

PyTree = Any
_COMMIT = "COMMITTED"

#: BC snapshot generations kept on disk (newest at ``path``, older at
#: ``path.g1``, ``path.g2``, …).  3 balances torn-write survival — one
#: torn newest + one bit-rotted older still leaves an intact resume
#: point — against disk for large-graph partial BC arrays.
DEFAULT_GENERATIONS = 3


def _path_str(kp) -> str:
    out = []
    for k in kp:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        else:
            out.append(str(k))
    return "/".join(out)


class Checkpointer:
    """Save/restore PyTrees of arrays; optionally asynchronous."""

    def __init__(self, root: str, async_writes: bool = False):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._async = async_writes
        self._queue: queue.Queue | None = None
        self._worker: threading.Thread | None = None
        self._errors: list[Exception] = []
        if async_writes:
            self._queue = queue.Queue()
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    # ------------------------------------------------------------- write
    def _drain(self):
        while True:
            item = self._queue.get()
            if item is None:
                return
            try:
                self._write(*item)
            except Exception as e:  # pragma: no cover - surfaced on wait()
                self._errors.append(e)
            finally:
                self._queue.task_done()

    def step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def save(self, step: int, state: PyTree, metadata: dict | None = None) -> str:
        """Snapshot state (device arrays are fetched to host first so the
        caller can keep training while an async write drains)."""
        leaves, treedef = jax.tree_util.tree_flatten_with_path(state)
        host_leaves = [(kp, np.asarray(v)) for kp, v in leaves]
        if self._async:
            self._queue.put((step, host_leaves, str(treedef), metadata or {}))
        else:
            self._write(step, host_leaves, str(treedef), metadata or {})
        return self.step_dir(step)

    def _write(self, step, host_leaves, treedef_str, metadata):
        d = self.step_dir(step)
        tmp = d + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        arrays = {}
        entries = []
        for kp, arr in host_leaves:
            key = _path_str(kp)
            logical_dtype = str(arr.dtype)
            # npz can't store ml_dtypes (bfloat16/fp8): persist a raw view
            if arr.dtype.kind == "V" or logical_dtype in (
                "bfloat16",
                "float8_e4m3fn",
                "float8_e5m2",
            ):
                arr = arr.view(np.uint8 if arr.dtype.itemsize == 1 else np.uint16)
            arrays[key] = arr
            entries.append(
                {
                    "key": key,
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "logical_dtype": logical_dtype,
                    "sha1": hashlib.sha1(np.ascontiguousarray(arr).tobytes()).hexdigest(),
                }
            )
        np.savez(os.path.join(tmp, "shard_p0.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(
                {
                    "step": step,
                    "treedef": treedef_str,
                    "leaves": entries,
                    "metadata": metadata,
                },
                f,
                indent=1,
            )
        with open(os.path.join(tmp, _COMMIT), "w") as f:
            f.write("ok")
        if os.path.exists(d):
            shutil.rmtree(d)
        os.replace(tmp, d)

    def wait(self) -> None:
        """Block until pending async writes land (re-raises failures)."""
        if self._queue is not None:
            self._queue.join()
        if self._errors:
            raise self._errors[0]

    def close(self) -> None:
        """Shut the worker down even when a queued write failed: wait()
        re-raises the write error, so the sentinel/join must run on the
        way out or the writer thread leaks past close()."""
        if self._queue is None:
            return
        try:
            self.wait()
        finally:
            self._queue.put(None)
            self._worker.join()

    # -------------------------------------------------------------- read
    def available_steps(self) -> list[int]:
        steps = []
        if not os.path.isdir(self.root):
            return steps
        for name in os.listdir(self.root):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.root, name, _COMMIT)):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def restore(self, like: PyTree, step: int | None = None) -> tuple[PyTree, dict]:
        """Restore into the structure of ``like`` (validates keys+hashes).

        Returns (state, metadata)."""
        steps = self.available_steps()
        if not steps:
            raise FileNotFoundError(f"no committed checkpoints under {self.root}")
        step = steps[-1] if step is None else step
        d = self.step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(d, "shard_p0.npz")) as z:
            arrays = {k: z[k] for k in z.files}
        by_key = {e["key"]: e for e in manifest["leaves"]}
        for key, arr in arrays.items():
            want = by_key[key]["sha1"]
            got = hashlib.sha1(np.ascontiguousarray(arr).tobytes()).hexdigest()
            if want != got:
                raise IOError(f"checkpoint corruption in {key} at step {step}")

        leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
        restored = []
        for kp, leaf in leaves:
            key = _path_str(kp)
            if key not in arrays:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = arrays[key]
            logical = by_key[key].get("logical_dtype", by_key[key]["dtype"])
            if logical != str(arr.dtype):  # restore ml_dtypes views
                import ml_dtypes

                arr = arr.view(np.dtype(getattr(ml_dtypes, logical)))
            want_shape = tuple(getattr(leaf, "shape", arr.shape))
            if tuple(arr.shape) != want_shape:
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs {want_shape}"
                )
            restored.append(arr)
        state = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like), restored
        )
        return state, manifest["metadata"]


class CheckpointManager:
    """Retention + auto-resume policy on top of Checkpointer."""

    def __init__(
        self,
        root: str,
        keep_last: int = 3,
        save_every: int = 100,
        async_writes: bool = False,
    ):
        self.ckpt = Checkpointer(root, async_writes=async_writes)
        self.keep_last = keep_last
        self.save_every = save_every

    def maybe_save(self, step: int, state: PyTree, metadata: dict | None = None) -> bool:
        if step % self.save_every != 0:
            return False
        self.ckpt.save(step, state, metadata)
        self.ckpt.wait()
        self._gc()
        return True

    def _gc(self) -> None:
        steps = self.ckpt.available_steps()
        for s in steps[: -self.keep_last]:
            shutil.rmtree(self.ckpt.step_dir(s))

    def latest_step(self) -> int | None:
        steps = self.ckpt.available_steps()
        return steps[-1] if steps else None

    def restore_or_init(self, init_state: PyTree) -> tuple[PyTree, dict, int]:
        """(state, metadata, start_step) — exact resume when possible."""
        step = self.latest_step()
        if step is None:
            return init_state, {}, 0
        state, meta = self.ckpt.restore(init_state, step)
        return state, meta, step + 1


class BCCheckpoint:
    """Durable (partial BC, n_s bookkeeping, committed rounds) triple.

    A ledger alone is not enough to resume BC: the committed rounds'
    *contributions* live in the (volatile) device accumulator.  The
    shared round loop (:class:`repro.core.driver.BCDriver`) therefore
    periodically snapshots a consistent prefix — the drained rounds'
    summed BC, their per-root component sizes, and exactly that round
    set — through this object; a restarted run seeds the driver from the
    snapshot and re-deals only the uncommitted rounds.  Consistency
    invariant: the stored bc/ns always correspond exactly to the stored
    committed set (snapshots happen only after the in-flight queue is
    fully drained), so a crash between snapshots merely redoes the tail.
    The stored bc is correction-free (the 1-degree analytic credits are
    pure post-processing and are re-applied on every finalize).

    Round ids are only meaningful relative to one schedule, so every
    snapshot carries a schedule fingerprint (see
    :func:`repro.distributed.fault_tolerance.schedule_fingerprint`);
    resuming against a different schedule — other graph, batch size or
    heuristics — raises instead of silently mixing incompatible partial
    sums.

    **Ledger namespacing.**  Under the multi-ledger straggler scheduler
    each replica commits into its own ledger; ``save`` accepts either a
    flat committed list (one shared ledger) or a list of per-replica
    lists, stored as ``committed_r{i}`` alongside the merged union under
    the legacy ``committed`` key.  :meth:`load` returns the union — a
    round committed by *any* replica (including one that stole or was
    re-dealt the round before the kill) is never re-accumulated — while
    :meth:`load_namespaced` returns the per-replica sets so a resumed
    multi-ledger driver keeps its commit attribution.  The straggler
    policy and replica count may differ across the resume: exactly-once
    only needs the union.

    **Generations & integrity.**  A single snapshot file makes a torn
    write (kill mid-flush, disk full) total loss, so ``save`` rotates
    the last ``generations`` snapshots — newest always at ``path``
    (legacy layout), older shifted to ``path.g1``, ``path.g2``, … —
    and embeds a per-array sha1 manifest (same scheme as
    :class:`Checkpointer`'s ``manifest.json``).  ``load`` walks newest →
    oldest, validates hashes, and resumes from the first intact
    generation with a logged warning for every one it skips; only when
    *every* generation is gone/corrupt does it cold-start (again warned,
    never a traceback).  :attr:`loaded_generation` records which one the
    last load used (0 = newest, None = cold start) so the driver can
    report it in ``BCResult.recovery_stats``.  A *readable* snapshot
    whose fingerprint mismatches still raises ValueError — that is a
    configuration error, not corruption, and older generations would
    only mask it.
    """

    def __init__(self, path: str, generations: int = DEFAULT_GENERATIONS):
        self.path = path
        self.generations = max(1, int(generations))
        #: generation index the last load() resumed from (None = cold).
        self.loaded_generation: int | None = None
        #: recovery-telemetry dict the last load() found in the snapshot
        #: (None when absent) — the driver resumes its counters from it
        #: so retry/quarantine/re-mesh history survives kill-and-resume.
        self.loaded_stats: dict | None = None

    def generation_paths(self) -> list[str]:
        """Snapshot paths newest → oldest (``path``, ``path.g1``, …)."""
        return [self.path] + [
            f"{self.path}.g{i}" for i in range(1, self.generations)
        ]

    def exists(self) -> bool:
        return any(os.path.exists(p) for p in self.generation_paths())

    def _read_validated(self, path: str) -> dict:
        """Load one snapshot file and verify its manifest hashes.

        Raises (IOError or whatever np.load raises) on torn/garbled
        files; pre-generational snapshots carry no manifest and are
        accepted as-is for compatibility.
        """
        with np.load(path, allow_pickle=False) as z:
            arrays = {k: z[k] for k in z.files}
        missing = [
            k for k in ("bc", "ns_roots", "ns_vals", "fingerprint")
            if k not in arrays
        ]
        if missing:
            raise IOError(f"snapshot {path} missing arrays {missing}")
        if "manifest" in arrays:
            manifest = json.loads(str(arrays["manifest"]))
            for key, want in manifest["sha1"].items():
                if key not in arrays:
                    raise IOError(
                        f"snapshot {path} missing array {key!r} named in manifest"
                    )
                got = hashlib.sha1(
                    np.ascontiguousarray(arrays[key]).tobytes()
                ).hexdigest()
                if got != want:
                    raise IOError(f"snapshot {path}: sha1 mismatch in {key!r}")
        return arrays

    def load(self, expected_fingerprint: str | None = None):
        """Returns (bc f64 [n] | None, ns_by_root dict, committed list).

        ``committed`` is the union over all replica ledgers.  Raises
        ValueError when the snapshot was written for a different schedule
        than ``expected_fingerprint``.
        """
        bc, ns_by_root, by_ledger = self.load_namespaced(expected_fingerprint)
        return bc, ns_by_root, sorted({r for lane in by_ledger for r in lane})

    def load_namespaced(self, expected_fingerprint: str | None = None):
        """Returns (bc | None, ns_by_root, committed_by_ledger).

        ``committed_by_ledger`` is a list of per-replica committed-round
        lists; a snapshot written by the single-ledger loop loads as one
        ledger.  Same fingerprint semantics as :meth:`load`.  Walks the
        generations newest → oldest past corrupt files (warned, never
        raised); an empty/unrecoverable state returns the cold-start
        triple ``(None, {}, [])``.
        """
        self.loaded_generation = None
        self.loaded_stats = None
        candidates = [
            (gen, p)
            for gen, p in enumerate(self.generation_paths())
            if os.path.exists(p)
        ]
        if not candidates:
            return None, {}, []
        for gen, p in candidates:
            try:
                arrays = self._read_validated(p)
            except Exception as e:
                log.warning(
                    "BCCheckpoint: snapshot %s unreadable (%s: %s); "
                    "falling back to an older generation",
                    p, type(e).__name__, e,
                )
                continue
            stored = str(arrays["fingerprint"])
            if expected_fingerprint is not None and stored != expected_fingerprint:
                raise ValueError(
                    f"checkpoint {p} was written for a different "
                    f"schedule (stored {stored}, expected "
                    f"{expected_fingerprint}) — same graph, batch size and "
                    f"heuristics are required to resume"
                )
            bc = arrays["bc"].astype(np.float64)
            ns_by_root = {
                int(r): float(v)
                for r, v in zip(arrays["ns_roots"], arrays["ns_vals"])
            }
            if "ledger_count" in arrays:
                by_ledger = [
                    [int(r) for r in arrays[f"committed_r{i}"]]
                    for i in range(int(arrays["ledger_count"]))
                ]
            else:  # legacy single-ledger snapshot
                by_ledger = [[int(r) for r in arrays["committed"]]]
            if "recovery_stats" in arrays:
                try:
                    self.loaded_stats = json.loads(str(arrays["recovery_stats"]))
                except Exception:  # telemetry is advisory, never fatal
                    self.loaded_stats = None
            self.loaded_generation = gen
            if gen > 0:
                log.warning(
                    "BCCheckpoint: resumed from generation %d (%s); newer "
                    "snapshots were corrupt", gen, p,
                )
            return bc, ns_by_root, by_ledger
        log.warning(
            "BCCheckpoint: no intact snapshot generation at %s; cold start",
            self.path,
        )
        return None, {}, []

    def save(
        self, bc, ns_by_root: dict, committed, fingerprint: str,
        *, stats: dict | None = None,
    ) -> None:
        """``committed``: flat list[int] (one ledger) or list of per-replica
        lists (multi-ledger).  ``stats`` (optional) is a JSON-serializable
        recovery-telemetry dict stored under the manifest's hash cover so
        the driver's counters survive kill-and-resume.  Writes atomically
        (tmp + rename) and rotates the previous snapshots one generation
        older."""
        roots = np.asarray(sorted(ns_by_root), np.int64)
        vals = np.asarray([ns_by_root[int(r)] for r in roots], np.float64)
        committed = list(committed)
        nested = bool(committed) and isinstance(
            committed[0], (list, tuple, np.ndarray)
        )
        by_ledger = (
            [[int(r) for r in lane] for lane in committed]
            if nested
            else [[int(r) for r in committed]]
        )
        union = sorted({rid for lane in by_ledger for rid in lane})
        arrays = {
            "bc": np.asarray(bc, np.float64),
            "ns_roots": roots,
            "ns_vals": vals,
            "committed": np.asarray(union, np.int64),
            "fingerprint": np.asarray(fingerprint),
            "ledger_count": np.asarray(len(by_ledger), np.int64),
        }
        for i, lane in enumerate(by_ledger):
            arrays[f"committed_r{i}"] = np.asarray(sorted(lane), np.int64)
        if stats is not None:
            arrays["recovery_stats"] = np.asarray(json.dumps(stats))
        arrays["manifest"] = np.asarray(
            json.dumps(
                {
                    "sha1": {
                        k: hashlib.sha1(
                            np.ascontiguousarray(v).tobytes()
                        ).hexdigest()
                        for k, v in arrays.items()
                    }
                }
            )
        )
        tmp = f"{self.path}.tmp.npz"
        np.savez(tmp, **arrays)
        # rotate oldest-first so each os.replace lands on a free slot
        gens = self.generation_paths()
        for newer, older in zip(gens[-2::-1], gens[:0:-1]):
            if os.path.exists(newer):
                os.replace(newer, older)
        os.replace(tmp, self.path)
