"""Checkpointing substrate: sharded npz save/restore, async writer,
retention, exact resume, and the BC round snapshot (per-replica ledger
namespacing for the straggler scheduler)."""
from repro.checkpoint.checkpointer import (
    BCCheckpoint,
    Checkpointer,
    CheckpointManager,
)

__all__ = ["Checkpointer", "CheckpointManager", "BCCheckpoint"]
