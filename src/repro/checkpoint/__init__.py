"""Checkpointing substrate: sharded npz save/restore, async writer,
retention, exact resume."""
from repro.checkpoint.checkpointer import Checkpointer, CheckpointManager

__all__ = ["Checkpointer", "CheckpointManager"]
