"""repro — Scalable Betweenness Centrality on multi-pod TPU systems.

A production-grade JAX reproduction (and extension) of Vella, Carbone &
Bernaschi, "Algorithms and Heuristics for Scalable Betweenness Centrality
Computation on Multi-GPU Systems" (2016), plus the training/serving
substrate for the ten assigned architectures.  See DESIGN.md.
"""

__version__ = "1.0.0"
