"""Source-sampled approximate BC: root-subset plans and stop rules.

Brandes' outer loop is a sum of independent per-root contributions, so a
uniform k-subset of the eligible roots gives the textbook unbiased
estimator  BC_hat(v) = (N / k) · Σ_{s ∈ sample} contribution_s(v)
(Brandes & Pich 2007; the paper's O(nm) exact cost — arxiv 1602.00963 —
is what makes this the only road to serving-scale graphs).  This module
owns the *plan* side of that estimator:

* :func:`plan_sampling` draws the seeded root subset as a **prefix of a
  seeded permutation** — samples for the same seed are *nested*
  (k' > k ⇒ sample_k ⊂ sample_k'), so a serving refresh that grows k
  strictly extends the already-accumulated evidence;
* :func:`rank_stability` is the top-k rank-agreement metric (Jaccard of
  the top-k sets, or a Kendall-tau-style pairwise concordance over their
  union) that the adaptive mode watches;
* :class:`AdaptiveStopRule` / :class:`BlockBudgetStop` are
  ``BCDriver(stop_rule=...)`` seam implementations — plain callables
  ``(bc_running, blocks_done) -> bool`` consulted after every drained
  dispatch block, next to the straggler/integrity seams, so checkpoints,
  chaos and the re-deal compose unchanged.

The *rescale* side lives with the entrypoints: they divide the eligible
count by ``BCResult.roots_accumulated`` (the roots actually committed,
which an adaptive stop truncates) so fixed and adaptive runs share one
calibration formula and ``sample_frac=1.0`` is exactly scale 1.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "SAMPLING_MODES",
    "RANK_METHODS",
    "normalize_sampling",
    "eligible_roots",
    "resolve_sample_size",
    "SamplePlan",
    "plan_sampling",
    "top_k_indices",
    "rank_stability",
    "AdaptiveStopRule",
    "BlockBudgetStop",
]

#: Source-sampling modes of both BC entrypoints (the single source of
#: truth for ``--sampling`` choices and the docs drift check,
#: tools/check_docs.py).  ``"off"`` runs every eligible root (the exact
#: path).  ``"fixed"`` runs a seeded k-root subset (``sample_frac`` /
#: ``sample_k``) and rescales by N/k.  ``"adaptive"`` additionally stops
#: dispatching new round blocks once the running accumulator's top-k
#: rank set stabilizes across consecutive blocks (AdaptiveStopRule),
#: rescaling by the roots actually accumulated.
SAMPLING_MODES = ("off", "fixed", "adaptive")

#: rank-agreement metrics accepted by :func:`rank_stability`
RANK_METHODS = ("jaccard", "kendall")


def normalize_sampling(mode: str | None) -> str:
    """Validate a sampling mode string (None means "off")."""
    mode = "off" if mode is None else mode
    if mode not in SAMPLING_MODES:
        raise ValueError(
            f"unknown sampling mode {mode!r}; expected one of {SAMPLING_MODES}"
        )
    return mode


def eligible_roots(graph) -> np.ndarray:
    """Traversal-worthy source ids under ``heuristics="h0"`` (degree ≥ 1).

    Matches the scheduler's eligibility rule on the un-reduced graph —
    sampling is restricted to "h0" precisely so the eligible pool (and
    with it the N in the N/k rescale) is root-separable.
    """
    return np.nonzero(graph.degrees() >= 1)[0].astype(np.int64)


def resolve_sample_size(
    num_eligible: int,
    sample_frac: float | None = None,
    sample_k: int | None = None,
) -> int:
    """Resolve the sample size k from exactly one of frac / k."""
    if sample_frac is not None and sample_k is not None:
        raise ValueError("pass sample_frac or sample_k, not both")
    if sample_k is not None:
        k = int(sample_k)
        if k < 1:
            raise ValueError(f"sample_k must be >= 1, got {sample_k}")
        if k > num_eligible:
            raise ValueError(
                f"sample_k={k} exceeds the {num_eligible} eligible roots"
            )
        return k
    frac = 1.0 if sample_frac is None else float(sample_frac)
    if not 0.0 < frac <= 1.0:
        raise ValueError(f"sample_frac must be in (0, 1], got {sample_frac}")
    return max(1, min(num_eligible, int(round(frac * num_eligible))))


@dataclasses.dataclass(frozen=True)
class SamplePlan:
    """A resolved root-sampling decision.

    ``roots`` is None when the sample is the full eligible pool — the
    schedule is then *identical* to the unsampled one (rescaling
    invariance: ``sample_frac=1.0`` has no sampled code path left).
    """

    mode: str  # one of SAMPLING_MODES
    roots: np.ndarray | None  # sorted sampled root ids; None = all eligible
    num_eligible: int
    k: int  # sample size (== num_eligible when roots is None)
    seed: int

    @property
    def scale(self) -> float:
        """The a-priori estimator rescale N/k (the entrypoints recompute
        it from the roots *actually* accumulated, which an adaptive stop
        truncates — for a completed fixed run the two agree)."""
        return self.num_eligible / self.k if self.k else 1.0


def plan_sampling(
    eligible: np.ndarray,
    mode: str,
    sample_frac: float | None = None,
    sample_k: int | None = None,
    seed: int = 0,
) -> SamplePlan:
    """Draw the seeded root subset for a sampled run.

    The sample is the first k entries of a seeded permutation of the
    eligible pool, so samples of the same seed are nested in k — growing
    a serving snapshot's sample strictly extends the old one.  Returned
    roots are sorted (the scheduler packs by its own order anyway; a
    sorted subset keeps schedules reproducible independent of draw
    order).
    """
    mode = normalize_sampling(mode)
    eligible = np.asarray(eligible, np.int64)
    num_eligible = int(eligible.size)
    if mode == "off":
        return SamplePlan(
            mode=mode, roots=None, num_eligible=num_eligible,
            k=num_eligible, seed=seed,
        )
    if num_eligible == 0:
        raise ValueError("cannot sample roots from a graph with no edges")
    if mode == "adaptive" and sample_frac is None and sample_k is None:
        sample_frac = 1.0  # adaptive defaults to the full pool; the stop
        # rule — not the draw — decides how much of it actually runs
    k = resolve_sample_size(num_eligible, sample_frac, sample_k)
    if k >= num_eligible:
        roots = None  # exact-schedule identity, no rescale drift
    else:
        rng = np.random.default_rng(seed)
        roots = np.sort(rng.permutation(eligible)[:k])
    return SamplePlan(
        mode=mode, roots=roots, num_eligible=num_eligible, k=k, seed=seed
    )


def top_k_indices(scores: np.ndarray, k: int) -> np.ndarray:
    """Indices of the k largest scores, ties broken by lowest vertex id
    (deterministic across runs and accumulation orders)."""
    scores = np.asarray(scores)
    k = min(int(k), scores.size)
    # lexsort: primary key -scores ascending == scores descending,
    # secondary key vertex id ascending
    order = np.lexsort((np.arange(scores.size), -scores))
    return order[:k]


def rank_stability(
    prev: np.ndarray, cur: np.ndarray, k: int = 10, method: str = "jaccard"
) -> float:
    """Rank agreement of two score vectors' top-k, in [0, 1]; 1.0 iff
    the top-k view is unchanged.

    ``"jaccard"``: |top-k(prev) ∩ top-k(cur)| / |union| — set stability,
    blind to order inside the top-k.  ``"kendall"``: fraction of
    concordant pairs over the union of the two top-k sets (a bounded
    Kendall-tau variant; ties concordant with ties) — also sensitive to
    reordering *within* the set.  Both are scale-invariant, so watching
    the unscaled running accumulator is equivalent to watching BC_hat.
    """
    if method not in RANK_METHODS:
        raise ValueError(
            f"unknown rank method {method!r}; expected one of {RANK_METHODS}"
        )
    a = top_k_indices(prev, k)
    b = top_k_indices(cur, k)
    union = np.union1d(a, b)
    if union.size == 0:
        return 1.0
    if method == "jaccard":
        inter = np.intersect1d(a, b, assume_unique=True).size
        return float(inter) / float(union.size)
    if union.size == 1:
        return 1.0
    pa = np.sign(np.asarray(prev, np.float64)[union][:, None]
                 - np.asarray(prev, np.float64)[union][None, :])
    pb = np.sign(np.asarray(cur, np.float64)[union][:, None]
                 - np.asarray(cur, np.float64)[union][None, :])
    iu = np.triu_indices(union.size, k=1)
    concordant = int((pa[iu] == pb[iu]).sum())
    return concordant / float(iu[0].size)


class AdaptiveStopRule:
    """``BCDriver`` stop-rule seam: stop once top-k ranks stabilize.

    Called as ``rule(bc_running, blocks_done)`` after each drained
    dispatch block with the running f64 accumulator.  The rule compares
    the accumulator's top-k against the previous check's
    (:func:`rank_stability`) and fires once the agreement has been
    ``>= threshold`` for ``window`` *consecutive* checks — but never
    before ``min_blocks`` dispatch blocks have completed, so a lucky
    first block cannot truncate the sample to something tiny.

    An unchanged accumulator scores exactly 1.0, so the default
    ``threshold=1.0`` means "the top-k set stopped moving".  Telemetry
    lands in ``stats`` (and, via the driver, ``BCResult.stop_stats``).
    """

    def __init__(
        self,
        top_k: int = 10,
        window: int = 2,
        min_blocks: int = 3,
        threshold: float = 1.0,
        method: str = "jaccard",
    ):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        if min_blocks < 1:
            raise ValueError(f"min_blocks must be >= 1, got {min_blocks}")
        if method not in RANK_METHODS:
            raise ValueError(
                f"unknown rank method {method!r}; expected one of {RANK_METHODS}"
            )
        self.top_k = int(top_k)
        self.window = int(window)
        self.min_blocks = int(min_blocks)
        self.threshold = float(threshold)
        self.method = method
        self._prev: np.ndarray | None = None
        self._streak = 0
        self.stats: dict = {
            "rule": "adaptive",
            "top_k": self.top_k,
            "window": self.window,
            "min_blocks": self.min_blocks,
            "threshold": self.threshold,
            "method": method,
            "checks": 0,
            "stability": [],  # per-check rank_stability history
            "fired_at_block": None,
        }

    def __call__(self, bc: np.ndarray, blocks_done: int) -> bool:
        bc = np.asarray(bc, np.float64)
        self.stats["checks"] += 1
        if self._prev is not None:
            s = rank_stability(self._prev, bc, self.top_k, self.method)
            self.stats["stability"].append(float(s))
            self._streak = self._streak + 1 if s >= self.threshold else 0
        self._prev = bc.copy()
        fire = blocks_done >= self.min_blocks and self._streak >= self.window
        if fire and self.stats["fired_at_block"] is None:
            self.stats["fired_at_block"] = int(blocks_done)
        return fire


class BlockBudgetStop:
    """Stop after a fixed number of dispatch blocks (serving refresh
    slices: each background generation runs ``max_blocks`` more blocks
    of the *same* checkpointed schedule, then publishes)."""

    def __init__(self, max_blocks: int):
        if max_blocks < 1:
            raise ValueError(f"max_blocks must be >= 1, got {max_blocks}")
        self.max_blocks = int(max_blocks)
        self.stats: dict = {
            "rule": "budget",
            "max_blocks": self.max_blocks,
            "checks": 0,
            "fired_at_block": None,
        }

    def __call__(self, bc: np.ndarray, blocks_done: int) -> bool:
        del bc
        self.stats["checks"] += 1
        fire = blocks_done >= self.max_blocks
        if fire and self.stats["fired_at_block"] is None:
            self.stats["fired_at_block"] = int(blocks_done)
        return fire
