"""Approximate-BC serving: source sampling, adaptive stopping, and the
versioned snapshot store behind ``launch/serve_bc.py``.

``sampling`` owns the estimator plan (seeded nested root subsets, the
N/k rescale contract, rank-stability metrics and the ``BCDriver``
``stop_rule`` seam implementations); ``store`` owns the atomic
generation-swapped :class:`BCSnapshotStore` that serves top-k and
per-vertex queries while a background driver refines the estimate.
"""
from repro.serving.sampling import (
    SAMPLING_MODES,
    AdaptiveStopRule,
    BlockBudgetStop,
    SamplePlan,
    eligible_roots,
    normalize_sampling,
    plan_sampling,
    rank_stability,
    resolve_sample_size,
    top_k_indices,
)
from repro.serving.store import BCSnapshot, BCSnapshotStore

__all__ = [
    "SAMPLING_MODES",
    "AdaptiveStopRule",
    "BlockBudgetStop",
    "SamplePlan",
    "eligible_roots",
    "normalize_sampling",
    "plan_sampling",
    "rank_stability",
    "resolve_sample_size",
    "top_k_indices",
    "BCSnapshot",
    "BCSnapshotStore",
]
