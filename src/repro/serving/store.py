"""Versioned in-memory BC snapshots for the serving front end.

A :class:`BCSnapshotStore` holds exactly one *immutable* current
snapshot and swaps it atomically when the background refresher publishes
a new generation: a publish builds the :class:`BCSnapshot` completely
and then replaces the store's single reference, so a reader that grabbed
the old reference keeps a self-consistent view forever and a reader
arriving mid-publish sees either the old or the new generation — never a
mix (the atomicity test in tests/test_serving.py races a reader against
a publisher to prove it).

Queries account themselves in ``stats`` — every query is exactly one of
``hits`` (served from a settled snapshot), ``stale_hits`` (served while
a refresh is in flight: the answer is valid but a fresher generation is
seconds away — the serving layer's X-Cache-Status: STALE analogue), or
``misses`` (no snapshot published yet), so
``queries == hits + stale_hits + misses`` always holds.

Durability comes from composing with
:class:`repro.checkpoint.checkpointer.BCCheckpoint`:
:meth:`BCSnapshotStore.publish_from_checkpoint` turns the checkpoint's
latest committed prefix (bc accumulator + per-root component sizes) into
a published generation, which is how a killed background refresher's
replacement resumes serving from the last *committed* state instead of
recomputing from scratch.
"""
from __future__ import annotations

import dataclasses
import threading

import numpy as np

__all__ = ["BCSnapshot", "BCSnapshotStore"]


@dataclasses.dataclass(frozen=True)
class BCSnapshot:
    """One immutable published generation (treat ``bc`` as read-only)."""

    generation: int
    bc: np.ndarray  # f64 [n] rescaled BC estimate
    meta: dict


class BCSnapshotStore:
    """Single-slot versioned snapshot store (see module docstring).

    Readers never take the write lock: the current snapshot is one
    attribute read (atomic under the GIL), and snapshots are immutable
    once published.  The write lock only serializes publishers so
    generation numbers stay monotonic.
    """

    def __init__(self):
        self._current: BCSnapshot | None = None
        self._write_lock = threading.Lock()
        self._stats_lock = threading.Lock()
        self._refreshing = False
        self.stats: dict = {
            "queries": 0,
            "hits": 0,
            "misses": 0,
            "stale_hits": 0,
            "publishes": 0,
        }

    # ------------------------------------------------------- publishing
    def publish(self, bc: np.ndarray, meta: dict | None = None) -> int:
        """Atomically swap in a new generation; returns its number."""
        bc = np.array(bc, np.float64, copy=True)  # immutable by isolation
        with self._write_lock:
            gen = (self._current.generation if self._current else 0) + 1
            snap = BCSnapshot(generation=gen, bc=bc, meta=dict(meta or {}))
            # the swap: one reference assignment — readers see old or new,
            # never a partially-built snapshot
            self._current = snap
        with self._stats_lock:
            self.stats["publishes"] += 1
        return gen

    def publish_from_checkpoint(
        self,
        checkpoint,
        fingerprint: str | None = None,
        *,
        num_eligible: int | None = None,
        meta: dict | None = None,
    ) -> int | None:
        """Publish the checkpoint's latest committed prefix (resume path).

        The checkpoint stores the *raw* (unscaled) accumulator; with
        ``num_eligible`` the estimator rescale N/k is recomputed here
        from the committed per-root component-size ledger (one entry per
        accumulated root under "h0" — the only heuristics mode sampling
        composes with).  Returns the published generation, or None when
        no readable snapshot exists (cold start).
        """
        bc, ns_by_root, committed = checkpoint.load(fingerprint)
        if bc is None:
            return None
        roots_done = len(ns_by_root)
        scale = 1.0
        if num_eligible is not None and roots_done:
            scale = float(num_eligible) / float(roots_done)
        info = {
            "source": "checkpoint",
            "checkpoint_generation": getattr(
                checkpoint, "loaded_generation", None
            ),
            "committed_rounds": len(committed),
            "roots_accumulated": roots_done,
            "scale": scale,
        }
        info.update(meta or {})
        return self.publish(bc * scale if scale != 1.0 else bc, info)

    # ------------------------------------------------ refresh lifecycle
    def begin_refresh(self) -> None:
        """Mark a background refresh in flight: queries served until
        :meth:`end_refresh` count as ``stale_hits``."""
        self._refreshing = True

    def end_refresh(self) -> None:
        self._refreshing = False

    @property
    def refreshing(self) -> bool:
        return self._refreshing

    # ---------------------------------------------------------- queries
    @property
    def generation(self) -> int:
        snap = self._current
        return snap.generation if snap else 0

    def snapshot(self) -> BCSnapshot | None:
        """The current snapshot reference, without query accounting
        (internal/test hook; serving queries go through top_k/score)."""
        return self._current

    def _account(self, snap: BCSnapshot | None) -> None:
        with self._stats_lock:
            self.stats["queries"] += 1
            if snap is None:
                self.stats["misses"] += 1
            elif self._refreshing:
                self.stats["stale_hits"] += 1
            else:
                self.stats["hits"] += 1

    def top_k(self, k: int) -> tuple[BCSnapshot, list[tuple[int, float]]] | None:
        """The k highest-BC vertices of the current generation as
        ``(snapshot, [(vertex, score), ...])`` — the snapshot rides along
        so the caller knows which generation answered.  None on a miss.
        """
        snap = self._current  # grab the reference once: self-consistent
        self._account(snap)
        if snap is None:
            return None
        from repro.serving.sampling import top_k_indices

        idx = top_k_indices(snap.bc, k)
        return snap, [(int(v), float(snap.bc[v])) for v in idx]

    def score(self, vertex: int) -> tuple[BCSnapshot, float] | None:
        """One vertex's BC estimate from the current generation
        (``(snapshot, score)``), or None on a miss."""
        snap = self._current
        self._account(snap)
        if snap is None:
            return None
        return snap, float(snap.bc[int(vertex)])
