"""GIN (arXiv:1810.00826; paper tier): 5 layers, d_hidden=64, sum
aggregator, learnable epsilon — the TU-datasets configuration."""
from repro.configs.base import GNN_SHAPES, GNNArch
from repro.configs.registry import register

ARCH = GNNArch(
    name="gin-tu",
    kind="gin",
    n_layers=5,
    d_hidden=64,
    aggregator="sum",
    learnable_eps=True,
)

register(ARCH, GNN_SHAPES)
