"""Architecture configs (one module per assigned arch + the paper's own).

Importing this package populates the registry.
"""
from repro.configs import (  # noqa: F401
    bc_rmat,
    codeqwen15_7b,
    deepseek_coder_33b,
    dlrm_rm2,
    gat_cora,
    gemma_7b,
    gin_tu,
    granite_moe_1b_a400m,
    graphcast,
    llama4_maverick_400b_a17b,
    meshgraphnet,
)
from repro.configs.base import *  # noqa: F401,F403
from repro.configs.registry import ArchBundle, get_arch, list_archs

__all__ = ["ArchBundle", "get_arch", "list_archs"]
