"""Config dataclasses + shape specs for all assigned architectures.

Every architecture file under repro/configs/ instantiates one of the
Arch dataclasses with the exact published hyperparameters and registers
it (registry.py).  Shapes are per-family workload definitions
(assignment block): each (arch × shape) pair is one dry-run cell.
"""
from __future__ import annotations

import dataclasses

__all__ = [
    "MoESpec",
    "LMArch",
    "LMShape",
    "GNNArch",
    "GNNShape",
    "DLRMArch",
    "DLRMShape",
    "BCArch",
    "BCShape",
    "LM_SHAPES",
    "GNN_SHAPES",
    "DLRM_SHAPES",
    "BC_SHAPES",
]


@dataclasses.dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    d_ff: int  # per-expert hidden
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class LMArch:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int  # dense-FFN hidden (ignored when moe is set)
    vocab: int
    activation: str = "silu"  # "silu"=SwiGLU, "gelu"=GeGLU
    moe: MoESpec | None = None
    rope_theta: float = 1e4
    optimizer: str = "adamw"  # "adamw" | "adafactor" (memory plan)
    remat: bool = True
    attn_window: int | None = None
    q_chunk: int = 512
    loss_chunk: int = 512  # sequence chunking of the CE loss

    @property
    def family(self) -> str:
        return "lm"


@dataclasses.dataclass(frozen=True)
class LMShape:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


LM_SHAPES = (
    LMShape("train_4k", "train", 4096, 256),
    LMShape("prefill_32k", "prefill", 32768, 32),
    LMShape("decode_32k", "decode", 32768, 128),
    LMShape("long_500k", "decode", 524288, 1),
)


@dataclasses.dataclass(frozen=True)
class GNNArch:
    name: str
    kind: str  # "graphcast" | "gat" | "gin" | "meshgraphnet"
    n_layers: int
    d_hidden: int
    n_heads: int = 1
    aggregator: str = "sum"  # "sum" | "attn" | "mean"
    mlp_layers: int = 2
    learnable_eps: bool = False  # GIN-ε
    mesh_refinement: int = 6  # graphcast multimesh level (metadata)
    n_vars: int = 227  # graphcast in/out channels

    @property
    def family(self) -> str:
        return "gnn"


@dataclasses.dataclass(frozen=True)
class GNNShape:
    name: str
    kind: str  # "full_graph" | "minibatch" | "batched_graphs"
    n_nodes: int
    n_edges: int
    d_feat: int
    n_classes: int = 47
    batch_nodes: int = 0  # minibatch target count
    fanout: tuple[int, ...] = ()
    n_graphs: int = 0  # batched_graphs


GNN_SHAPES = (
    GNNShape("full_graph_sm", "full_graph", 2_708, 10_556, 1_433, n_classes=7),
    GNNShape(
        "minibatch_lg",
        "minibatch",
        232_965,
        114_615_892,
        602,
        n_classes=41,
        batch_nodes=1_024,
        fanout=(15, 10),
    ),
    GNNShape("ogb_products", "full_graph", 2_449_029, 61_859_140, 100, n_classes=47),
    GNNShape("molecule", "batched_graphs", 30, 64, 64, n_classes=2, n_graphs=128),
)


@dataclasses.dataclass(frozen=True)
class DLRMArch:
    name: str
    n_dense: int
    n_sparse: int
    embed_dim: int
    bot_mlp: tuple[int, ...]
    top_mlp: tuple[int, ...]
    interaction: str = "dot"
    rows_per_table: int = 10_000_000
    hot_size: int = 1  # multi-hot pooling factor (EmbeddingBag L)

    @property
    def family(self) -> str:
        return "recsys"


@dataclasses.dataclass(frozen=True)
class DLRMShape:
    name: str
    kind: str  # "train" | "serve" | "retrieval"
    batch: int
    n_candidates: int = 0


DLRM_SHAPES = (
    DLRMShape("train_batch", "train", 65_536),
    DLRMShape("serve_p99", "serve", 512),
    DLRMShape("serve_bulk", "serve", 262_144),
    DLRMShape("retrieval_cand", "retrieval", 1, n_candidates=1_000_000),
)


@dataclasses.dataclass(frozen=True)
class BCArch:
    """The paper's own workload: MGBC on an R-MAT graph."""

    name: str
    scale: int
    edge_factor: int
    batch_size: int = 16  # concurrent sources per round
    heuristics: str = "h3"
    max_levels: int = 24  # static level bound for dry-run lowering

    @property
    def family(self) -> str:
        return "bc"


@dataclasses.dataclass(frozen=True)
class BCShape:
    name: str
    scale: int
    edge_factor: int


BC_SHAPES = (
    BCShape("rmat_s23_ef16", 23, 16),
    BCShape("rmat_s25_ef16", 25, 16),
)
