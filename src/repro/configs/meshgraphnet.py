"""MeshGraphNet (arXiv:2010.03409; unverified tier): 15 message-passing
layers, d_hidden=128, sum aggregator, 2-layer MLPs, residual edge+node
updates."""
from repro.configs.base import GNN_SHAPES, GNNArch
from repro.configs.registry import register

ARCH = GNNArch(
    name="meshgraphnet",
    kind="meshgraphnet",
    n_layers=15,
    d_hidden=128,
    aggregator="sum",
    mlp_layers=2,
)

register(ARCH, GNN_SHAPES)
