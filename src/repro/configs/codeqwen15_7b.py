"""CodeQwen1.5-7B dense LM (hf:Qwen/CodeQwen1.5-7B; hf tier).

32L d_model=4096 32H (GQA kv=32 — effectively MHA, head_dim=128),
d_ff=13440 SwiGLU, vocab=92416.
"""
from repro.configs.base import LM_SHAPES, LMArch
from repro.configs.registry import register

ARCH = LMArch(
    name="codeqwen1.5-7b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=13440,
    vocab=92416,
    activation="silu",
)

register(ARCH, LM_SHAPES)
