"""GraphCast-class mesh GNN (arXiv:2212.12794; unverified tier).

Encoder-processor-decoder on the icosahedral multimesh: 16 processor
layers, d_hidden=512, sum aggregation, 227 surface/atmo variables.
mesh_refinement=6 is metadata for the dataset generator.
"""
from repro.configs.base import GNN_SHAPES, GNNArch
from repro.configs.registry import register

ARCH = GNNArch(
    name="graphcast",
    kind="graphcast",
    n_layers=16,
    d_hidden=512,
    aggregator="sum",
    mesh_refinement=6,
    n_vars=227,
)

register(ARCH, GNN_SHAPES)
