"""Gemma-7B dense LM (arXiv:2403.08295; hf tier).

28L d_model=3072 16H (GQA kv=16, head_dim=256) d_ff=24576 GeGLU,
vocab=256000.  Note head_dim*heads (4096) != d_model (3072) — the o-proj
maps back.
"""
from repro.configs.base import LM_SHAPES, LMArch
from repro.configs.registry import register

ARCH = LMArch(
    name="gemma-7b",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab=256000,
    activation="gelu",
)

register(ARCH, LM_SHAPES)
