"""Llama-4 Maverick-class MoE LM (hf:meta-llama; unverified tier).

48L d_model=5120 40H (GQA kv=8, head_dim=128) vocab=202048,
MoE 128 experts top-1 with expert d_ff=8192.  Early-fusion multimodality
is out of scope for the LM backbone cells (text tokens only).
Adafactor is mandatory at this scale (DESIGN.md §6 memory plan).
"""
from repro.configs.base import LM_SHAPES, LMArch, MoESpec
from repro.configs.registry import register

ARCH = LMArch(
    name="llama4-maverick-400b-a17b",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    activation="silu",
    moe=MoESpec(num_experts=128, top_k=1, d_ff=8192, capacity_factor=1.25),
    optimizer="adafactor",
)

register(ARCH, LM_SHAPES)
