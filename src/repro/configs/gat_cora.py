"""GAT (arXiv:1710.10903; paper tier): 2 layers, 8 hidden x 8 heads,
attention aggregation — the Cora configuration."""
from repro.configs.base import GNN_SHAPES, GNNArch
from repro.configs.registry import register

ARCH = GNNArch(
    name="gat-cora",
    kind="gat",
    n_layers=2,
    d_hidden=8,
    n_heads=8,
    aggregator="attn",
)

register(ARCH, GNN_SHAPES)
