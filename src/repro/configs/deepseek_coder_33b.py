"""DeepSeek-Coder-33B dense LM (arXiv:2401.14196; hf tier).

62L d_model=7168 56H (GQA kv=8, head_dim=128) d_ff=19200 vocab=32256,
llama-style SwiGLU.
"""
from repro.configs.base import LM_SHAPES, LMArch
from repro.configs.registry import register

ARCH = LMArch(
    name="deepseek-coder-33b",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab=32256,
    activation="silu",
)

register(ARCH, LM_SHAPES)
