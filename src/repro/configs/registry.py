"""Architecture registry: ``--arch <id>`` resolution for all launchers."""
from __future__ import annotations

import dataclasses
from typing import Any

__all__ = ["ArchBundle", "register", "get_arch", "list_archs"]


@dataclasses.dataclass(frozen=True)
class ArchBundle:
    arch: Any
    shapes: dict[str, Any]  # shape-name -> shape spec

    @property
    def family(self) -> str:
        return self.arch.family


_REGISTRY: dict[str, ArchBundle] = {}


def register(arch, shapes) -> None:
    _REGISTRY[arch.name] = ArchBundle(arch=arch, shapes={s.name: s for s in shapes})


def get_arch(name: str) -> ArchBundle:
    import repro.configs  # noqa: F401 — populate registry

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    import repro.configs  # noqa: F401

    return sorted(_REGISTRY)
