"""DLRM RM2 (arXiv:1906.00091; paper tier).

13 dense + 26 sparse features, embed_dim=64, bottom MLP 13-512-256-64,
top MLP 512-512-256-1, dot interaction.  10M rows per table (RM2-class);
lookups go through the EmbeddingBag built in models/dlrm.py.
"""
from repro.configs.base import DLRM_SHAPES, DLRMArch
from repro.configs.registry import register

ARCH = DLRMArch(
    name="dlrm-rm2",
    n_dense=13,
    n_sparse=26,
    embed_dim=64,
    bot_mlp=(512, 256, 64),
    top_mlp=(512, 256, 1),
    interaction="dot",
    rows_per_table=10_485_760,  # 10x2^20: divides the 256/512-chip meshes
    hot_size=1,
)

register(ARCH, DLRM_SHAPES)
