"""The paper's own workload: MGBC on R-MAT graphs (paper §4.1/4.3).

SCALE 23/25, EF 16 — the strong-scaling configurations of Figs. 4-6.
Dry-run cells lower one full BC round (forward counting + dependency
accumulation, 2-D partitioned) with a static level bound.
"""
from repro.configs.base import BC_SHAPES, BCArch
from repro.configs.registry import register

ARCH = BCArch(
    name="bc-rmat",
    scale=23,
    edge_factor=16,
    batch_size=16,
    heuristics="h3",
    max_levels=12,  # R-MAT EF16 diameter ~6-8 (paper Table 1); was 24 — §Perf iteration A
)

register(ARCH, BC_SHAPES)
