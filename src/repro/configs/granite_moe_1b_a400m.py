"""IBM Granite-3.0 1b-a400m MoE LM (hf:ibm-granite; hf tier).

24L d_model=1024 16H (GQA kv=8, head_dim=64) vocab=49155,
MoE 32 experts top-8, expert d_ff=512.
"""
from repro.configs.base import LM_SHAPES, LMArch, MoESpec
from repro.configs.registry import register

ARCH = LMArch(
    name="granite-moe-1b-a400m",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab=49155,
    activation="silu",
    moe=MoESpec(num_experts=32, top_k=8, d_ff=512, capacity_factor=1.25),
)

register(ARCH, LM_SHAPES)
