# Tier-1 verification: the full test suite on CPU.  Pallas kernels run
# in interpret mode (the container validates kernel semantics; TPU
# executes them compiled), distributed tests use 8 host devices via the
# XLA flag set in tests/conftest.py.
verify:
	PYTHONPATH=src python -m pytest -x -q

test: verify

bench:
	PYTHONPATH=src:. python benchmarks/run.py

.PHONY: verify test bench
