# Tier-1 verification: the full test suite on CPU.  Pallas kernels run
# in interpret mode (the container validates kernel semantics; TPU
# executes them compiled), distributed tests use 8 host devices via the
# XLA flag set in tests/conftest.py.
verify:
	PYTHONPATH=src python -m pytest -x -q

test: verify

bench:
	PYTHONPATH=src:. python benchmarks/run.py

# Overlap + sub-cluster + sparse subsets (fig9 + table3 + fig4
# analogues): write BENCH_overlap.json, BENCH_subcluster.json (per-
# straggler-policy wall, rounds stolen/re-dealt, idle seconds recovered)
# and BENCH_sparse.json — the machine-readable perf trajectory future
# PRs regress against.  CI runs this as its bench smoke target.
bench-smoke:
	PYTHONPATH=src:. python benchmarks/run.py --only fig9
	PYTHONPATH=src:. python benchmarks/run.py --only table3
	PYTHONPATH=src:. python benchmarks/run.py --only fig4

# Documentation health: the quickstart must execute, and the engine /
# overlap / heuristics / straggler choice lists in README.md +
# ARCHITECTURE.md must match the source-of-truth constants.
docs-check:
	PYTHONPATH=src python examples/quickstart.py
	python tools/check_docs.py

.PHONY: verify test bench bench-smoke docs-check
