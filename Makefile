# Tier-1 verification: the full test suite on CPU, plus lint when ruff
# is available.  Pallas kernels run in interpret mode (the container
# validates kernel semantics; TPU executes them compiled), distributed
# tests use 8 host devices via the XLA flag set in tests/conftest.py.
verify:
	PYTHONPATH=src python -m pytest -x -q
	@if command -v ruff >/dev/null 2>&1; then $(MAKE) lint; \
	else echo "ruff not installed; skipping lint (the CI lint job runs it)"; fi

test: verify

# Style gate (config in pyproject.toml; the CI lint job runs this).
lint:
	ruff check .
	ruff format --check .

bench:
	PYTHONPATH=src:. python benchmarks/run.py

# Overlap + sub-cluster + sparse subsets (fig9 + table3 + fig4
# analogues): write BENCH_overlap.json, BENCH_subcluster.json (per-
# straggler-policy wall, rounds stolen/re-dealt, idle seconds recovered)
# and BENCH_sparse.json — the machine-readable perf trajectory future
# PRs regress against.  CI runs this as its bench smoke target.
bench-smoke:
	PYTHONPATH=src:. python benchmarks/run.py --only fig9
	PYTHONPATH=src:. python benchmarks/run.py --only table3
	PYTHONPATH=src:. python benchmarks/run.py --only fig4

# Perf-regression gate: regenerate the BENCH_*.json records, then
# compare them against the committed baselines — structural metrics
# (link bytes, ring steps, collective counts by class, tile counts,
# A-stream bytes, the hybrid cell decision) must match exactly,
# wall-clock within a loose factor.  A deliberate change commits the
# regenerated baseline in the same PR (tools/check_bench.py).
bench-check: bench-smoke
	python tools/check_bench.py

# Measured-cost autotune smoke: cold-measure -> cache-hit round trip on
# 8 fake host devices (tools/autotune_smoke.py) — proves the
# measure-once contract (second run hits, never re-measures) and BC
# parity under autotune.  Writes AUTOTUNE_cache.json (generated
# artifact; CI uploads it next to the BENCH baselines, never commit it).
autotune-smoke:
	PYTHONPATH=src:. python tools/autotune_smoke.py

# Fault-matrix smoke: every injectable fault class (transient, poison,
# kill, torn snapshot, corrupted autotune cache) end-to-end on 8 fake
# host devices (tools/chaos_smoke.py) — the self-healing round loop must
# keep BC parity with the Brandes oracle and report its recovery
# telemetry under each one.
chaos-smoke:
	PYTHONPATH=src:. python tools/chaos_smoke.py

# Snapshot-serving smoke: the sampled-BC serving front end end to end
# on 8 fake host devices (tools/serve_smoke.py) — a background
# refresher runs block-budgeted slices over a shared BCCheckpoint while
# a foreground loop queries the snapshot store; asserts full query
# accounting (hit/stale/miss), monotone atomic generation swaps,
# final-generation parity vs the Brandes oracle and the
# committed-snapshot resume path.
serve-smoke:
	PYTHONPATH=src:. python tools/serve_smoke.py

# Weighted-traversal smoke: bucketed (delta-stepping) BC vs the
# Dijkstra oracle on 8 fake host devices (tools/weighted_smoke.py) —
# single-device + distributed engines on a dyadic-weighted graph, plus
# the unit-weight bitwise reduction to the unweighted engine.
weighted-smoke:
	PYTHONPATH=src:. python tools/weighted_smoke.py

# CI shard map drift gate: every tests/test_*.py on disk must belong to
# exactly one shard in tools/ci_shards.py (the sharded CI matrix runs
# `--files <shard>` lists; a file in no shard would silently never run
# in the sharded job).
shard-check:
	python tools/ci_shards.py --check

# Documentation health: the quickstart must execute, and the engine /
# overlap / heuristics / straggler / autotune choice lists in README.md
# + ARCHITECTURE.md must match the source-of-truth constants.
docs-check:
	PYTHONPATH=src python examples/quickstart.py
	python tools/check_docs.py

.PHONY: verify test lint bench bench-smoke bench-check autotune-smoke \
	chaos-smoke serve-smoke weighted-smoke shard-check docs-check
